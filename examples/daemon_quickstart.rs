//! Daemon quickstart — the curl-free CI smoke.
//!
//! Spawns `sparrowrld` in-process on an ephemeral port, submits a tiny
//! deterministic syn-xs run over real loopback HTTP, polls it to
//! completion, prints the final checksum, and exits 0. Any failure
//! (submission rejected, run failed, timeout) exits nonzero.
//!
//! ```text
//! cargo run --release --example daemon_quickstart
//! ```

use sparrowrl::daemon::{http_get, http_post, Daemon, DaemonConfig};
use sparrowrl::util::json::Json;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let handle = Daemon::spawn(DaemonConfig {
        addr: "127.0.0.1:0".to_string(),
        ..DaemonConfig::default()
    })?;
    let addr = handle.addr();
    println!("sparrowrld on http://{addr}");

    let spec = "{\"model\":\"syn-xs\",\"steps\":3,\"sft_steps\":1,\"actors\":2,\
                \"group_size\":2,\"max_new_tokens\":5,\"seed\":42}";
    let resp = http_post(addr, "/runs", spec)?;
    anyhow::ensure!(resp.status == 201, "submission rejected: {} {}", resp.status, resp.body);
    let id = Json::parse(&resp.body)
        .map_err(|e| anyhow::anyhow!("bad submit body: {e}"))?
        .get("id")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("submit body has no id"))?
        .to_string();
    println!("submitted run {id}");

    let deadline = Instant::now() + Duration::from_secs(60);
    let checksum = loop {
        anyhow::ensure!(Instant::now() < deadline, "run {id} did not finish in 60s");
        let snap = http_get(addr, &format!("/runs/{id}"))?;
        anyhow::ensure!(snap.status == 200, "snapshot failed: {}", snap.status);
        let json = Json::parse(&snap.body).map_err(|e| anyhow::anyhow!("bad snapshot: {e}"))?;
        match json.get("status").and_then(Json::as_str) {
            Some("finished") => {
                break json
                    .get("final_checksum")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("finished without a checksum"))?
                    .to_string()
            }
            Some("failed") | Some("aborted") => {
                anyhow::bail!("run {id} ended abnormally: {}", snap.body)
            }
            _ => std::thread::sleep(Duration::from_millis(25)),
        }
    };
    println!("run {id} finished; final policy checksum {checksum}");

    let health = http_get(addr, "/healthz")?;
    anyhow::ensure!(health.status == 200, "daemon unhealthy after the run");
    handle.shutdown();
    println!("daemon smoke OK");
    Ok(())
}
