//! End-to-end validation driver (DESIGN.md "End-to-end validation"):
//! trains a real transformer through the full three-layer stack —
//! rust coordinator -> PJRT -> AOT-lowered JAX model -> Pallas attention —
//! for a few hundred steps on the synthetic reasoning corpus, logging the
//! loss curve, reward curve, and per-step update sparsity.
//!
//! Defaults run sparrow-s (~1.1M params) with 300 SFT + 60 RL steps in a
//! few minutes on CPU; pass `--model sparrow-xl` (after
//! `make artifacts MODELS=sparrow-xl`) for the ~116M-parameter version of
//! the same pipeline. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example e2e_rl_training -- --model sparrow-s --sft-steps 300 --rl-steps 60
//! ```

use sparrowrl::session::{Event, RunSpec, Session};
use sparrowrl::trainer::Algorithm;
use sparrowrl::util::cli::Args;
use sparrowrl::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.str_or("model", "sparrow-s");
    let algorithm = Algorithm::parse(&args.str_or("algorithm", "grpo")).unwrap();
    let plan = RunSpec::model(&model)
        .sft_steps(args.parse_or("sft-steps", 300u64))
        .steps(args.parse_or("rl-steps", 60u64))
        .lr_sft(args.parse_or("lr-sft", 3e-3f32))
        .lr_rl(args.parse_or("lr-rl", 2e-5f32))
        .actors(args.parse_or("actors", 2usize))
        .max_new_tokens(args.parse_or("max-new", 8usize))
        .seed(args.parse_or("seed", 0u64))
        .algorithm(algorithm)
        .build()?;

    let spec = sparrowrl::config::model(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    println!(
        "=== e2e RL training: {model} ({} params), {} SFT + {} RL steps, {} ===\n",
        spec.total_params(),
        plan.config().sft_steps,
        plan.config().steps,
        algorithm.name()
    );
    // Live per-step lines come off the session's event stream; the final
    // report is assembled from the same events.
    let mut session = Session::start(&plan)?;
    let report = loop {
        match session.recv() {
            Some(Event::StepCompleted(log)) => println!(
                "step {:>3}  loss {:>8.4}  reward {:.3}  rho {:.4}%  payload {}",
                log.step,
                log.loss,
                log.mean_reward,
                log.rho * 100.0,
                fmt_bytes(log.payload_bytes),
            ),
            Some(Event::Finished(r)) => break r,
            Some(_) => {}
            None => anyhow::bail!("session ended without a report"),
        }
    };

    println!("\n--- SFT loss curve (every 10th step) ---");
    for (i, l) in report.sft_losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.sft_losses.len() {
            println!("sft {i:>4}: {l:.4}");
        }
    }
    println!("\n--- RL phase ---");
    println!("step, loss, mean_reward, rho_pct, payload");
    for s in &report.steps {
        println!(
            "{:>4}, {:>8.4}, {:.3}, {:.4}, {}",
            s.step,
            s.loss,
            s.mean_reward,
            s.rho * 100.0,
            fmt_bytes(s.payload_bytes)
        );
    }
    let early: f32 = report
        .steps
        .iter()
        .take((report.steps.len() / 4).max(1))
        .map(|s| s.mean_reward)
        .sum::<f32>()
        / (report.steps.len() / 4).max(1) as f32;
    println!(
        "\nsummary: sft loss {:.3} -> {:.3}; reward {:.3} (first quarter) -> {:.3} (last quarter); \
         mean rho {:.3}%; mean payload {} ({}x under dense); wall {:.1}s",
        report.sft_losses.first().unwrap(),
        report.sft_losses.last().unwrap(),
        early,
        report.mean_reward_last_quarter(),
        report.mean_rho() * 100.0,
        fmt_bytes(
            report.steps.iter().map(|s| s.payload_bytes).sum::<u64>()
                / report.steps.len().max(1) as u64
        ),
        spec.dense_bytes_bf16()
            / (report.steps.iter().map(|s| s.payload_bytes).sum::<u64>()
                / report.steps.len().max(1) as u64)
                .max(1),
        report.wall_s
    );
    Ok(())
}
