//! Quickstart: the smallest end-to-end SparrowRL run.
//!
//! Loads the AOT artifacts for the smoke-size model, runs a short SFT
//! warmup plus a few RL steps with GRPO, and prints per-step sparsity and
//! delta payloads — the paper's core observation, live.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example quickstart
//! ```

use sparrowrl::rt::{run_local, LocalRunConfig};
use sparrowrl::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let mut cfg = LocalRunConfig::quick("sparrow-xs");
    cfg.sft_steps = 40;
    cfg.steps = 5;
    cfg.verbose = true;
    println!("SparrowRL quickstart: sparrow-xs, GRPO, 2 in-process actors\n");
    let report = run_local(&cfg)?;
    println!(
        "\nSFT warmup: loss {:.3} -> {:.3}",
        report.sft_losses.first().unwrap(),
        report.sft_losses.last().unwrap()
    );
    let spec = sparrowrl::config::model("sparrow-xs").unwrap();
    println!(
        "RL steps: mean update sparsity rho = {:.3}% of {} params",
        report.mean_rho() * 100.0,
        spec.total_params()
    );
    let last = report.steps.last().unwrap();
    println!(
        "last delta checkpoint: {} vs {} dense ({}x smaller), extracted in {:.1} ms",
        fmt_bytes(last.payload_bytes),
        fmt_bytes(last.dense_bytes),
        last.dense_bytes / last.payload_bytes.max(1),
        last.extract_ms
    );
    println!("every actor finished bit-exact with the trainer policy (asserted internally).");
    Ok(())
}
