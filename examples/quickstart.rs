//! Quickstart: the smallest end-to-end SparrowRL run, through the
//! Session API.
//!
//! Builds a validated `RunSpec`, starts a live `Session`, and subscribes
//! to its typed event stream — per-step sparsity and delta payloads (the
//! paper's core observation) printed as they happen, then the final
//! report assembled from those same events.
//!
//! With PJRT artifacts present (`make artifacts`) the run executes the
//! real sparrow-xs model; without them it falls back to the
//! deterministic synthetic engine so the example (and the CI
//! session-smoke job) always runs.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparrowrl::delta::ModelLayout;
use sparrowrl::rt::SyntheticCompute;
use sparrowrl::session::{Event, RunSpec, Session};
use sparrowrl::util::fmt_bytes;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let have_artifacts = sparrowrl::runtime::artifacts_dir()
        .join("sparrow-xs_policy_fwd.hlo.txt")
        .exists();
    let mut session = if have_artifacts {
        println!("SparrowRL quickstart: sparrow-xs, GRPO, 2 in-process actors\n");
        let plan = RunSpec::model("sparrow-xs").sft_steps(40).steps(5).build()?;
        Session::start(&plan)?
    } else {
        println!("SparrowRL quickstart: synthetic engine (artifacts missing), GRPO, 2 actors\n");
        let plan = RunSpec::synthetic()
            .sft_steps(10)
            .steps(5)
            .group_size(2)
            .max_new_tokens(6)
            .lr_rl(1e-2)
            .pipelined()
            .build()?;
        let layout = ModelLayout::transformer("syn-quickstart", 512, 128, 2, 256);
        let comp = SyntheticCompute::new(16, 8, 64)
            .with_delays(Duration::from_millis(5), Duration::from_millis(4));
        Session::start_with_compute(&plan, layout, comp)?
    };

    // Subscribe: the CLI-style per-step line is just one view of the
    // typed events; `Finished` carries the report assembled from them.
    let report = loop {
        match session.recv() {
            Some(Event::StepCompleted(log)) => println!(
                "step {:>3}  loss {:>8.4}  reward {:.3}  rho {:.4}%  payload {}",
                log.step,
                log.loss,
                log.mean_reward,
                log.rho * 100.0,
                fmt_bytes(log.payload_bytes),
            ),
            Some(Event::Committed { version, checksum }) => println!(
                "        committed v{version} ({})",
                &sparrowrl::util::hex(&checksum)[..12],
            ),
            Some(Event::Finished(report)) => break report,
            Some(_) => {}
            None => anyhow::bail!("session ended without a report"),
        }
    };

    println!(
        "\nSFT warmup: loss {:.3} -> {:.3}",
        report.sft_losses.first().unwrap(),
        report.sft_losses.last().unwrap()
    );
    println!("RL steps: mean update sparsity rho = {:.3}%", report.mean_rho() * 100.0);
    let last = report.steps.last().unwrap();
    println!(
        "last delta checkpoint: {} vs {} dense ({}x smaller), extracted in {:.1} ms",
        fmt_bytes(last.payload_bytes),
        fmt_bytes(last.dense_bytes),
        last.dense_bytes / last.payload_bytes.max(1),
        last.extract_ms
    );
    println!("final policy checksum: {}", last.checksum_hex());
    println!("every actor finished bit-exact with the trainer policy (asserted internally).");
    Ok(())
}
