//! Heterogeneous inference pool (§7.6): mixed A100 + L40 actors, uniform
//! vs Algorithm-1 scheduling, plus a straggler/preemption stress showing
//! the EMA estimator adapting shares over steps.
//!
//! ```bash
//! cargo run --release --example heterogeneous_pool
//! ```

use sparrowrl::config::{self, regions, GpuClass};
use sparrowrl::data::Benchmark;
use sparrowrl::delta::ModelLayout;
use sparrowrl::rt::SyntheticCompute;
use sparrowrl::scheduler::{Scheduler, SchedulerConfig, VersionState};
use sparrowrl::session::{Backend, Event, RunSpec, Session};
use sparrowrl::sim::driver::{run, FailureEvent, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::transport::{KillMode, KillSpec, TcpConfig};

fn main() -> anyhow::Result<()> {
    let model = config::model("qwen3-4b").unwrap();
    let pool = vec![
        GpuClass::A100,
        GpuClass::A100,
        GpuClass::A100,
        GpuClass::A100,
        GpuClass::L40,
        GpuClass::L40,
        GpuClass::L40,
        GpuClass::L40,
    ];

    println!("=== Heterogeneous pool: 4xA100 + 4xL40 serving qwen3-4b ===\n");
    for bench in [Benchmark::Gsm8k, Benchmark::DeepScaleR] {
        let mk = |hetero: bool| {
            let mut cfg = SimConfig::paper_testbed(
                model.clone(),
                bench,
                System::Sparrow,
                vec![RegionSpec::new(regions::CANADA, pool.clone())],
            );
            cfg.hetero_sched = hetero;
            cfg
        };
        let uniform = run(&mk(false)).throughput();
        let aware = run(&mk(true)).throughput();
        println!(
            "{:<12} uniform {:>8.0} t/s | heterogeneity-aware {:>8.0} t/s | +{:.1}%",
            bench.name(),
            uniform,
            aware,
            (aware / uniform - 1.0) * 100.0
        );
    }

    // The Algorithm-1 feedback loop in isolation: one actor starts
    // throttled, the EMA recovers its share as performance returns.
    println!("\n=== Algorithm 1 share adaptation (H100 + throttled A100) ===");
    let mut sched = Scheduler::new(SchedulerConfig::default());
    sched.register(0, 5000.0);
    sched.register(1, 2500.0);
    for step in 0..8u64 {
        sched.observe_version(0, VersionState { active: step, staged: None });
        sched.observe_version(1, VersionState { active: step, staged: None });
        let alloc = sched.allocate(step, 300);
        let shares: Vec<String> = alloc
            .iter()
            .map(|a| format!("actor{}={}", a.actor, a.requests))
            .collect();
        println!("step {step}: {}", shares.join("  "));
        // Actor 1 is thermally throttled for the first 4 steps.
        let a1_rate = if step < 4 { 800.0 } else { 2500.0 };
        for a in alloc {
            let rate = if a.actor == 0 { 5000.0 } else { a1_rate };
            let elapsed = a.requests as f64 * 300.0 / rate;
            sched.settle(a.actor, a.requests * 300, elapsed);
        }
    }

    // Failure injection: one L40 dies mid-run; leases migrate its work.
    println!("\n=== Actor preemption at step 3 (lease-based recovery) ===");
    let mut cfg = SimConfig::paper_testbed(
        model.clone(),
        Benchmark::Gsm8k,
        System::Sparrow,
        vec![RegionSpec::new(regions::CANADA, pool)],
    );
    cfg.failures = vec![FailureEvent { actor: 7, step: 3 }];
    let faulty = run(&cfg);
    cfg.failures.clear();
    let healthy = run(&cfg);
    println!(
        "healthy: {:.0} t/s in {:.0}s | with preemption: {:.0} t/s in {:.0}s (all {} tokens still produced)",
        healthy.throughput(),
        healthy.total_time,
        faulty.throughput(),
        faulty.total_time,
        faulty.total_gen_tokens
    );

    // The same recovery executed for real: a 3-actor deterministic run
    // over loopback sockets, one actor crashed mid-final-step. The
    // Session event stream surfaces the failover; the committed policy
    // checksum still matches the no-failure baseline bit for bit.
    println!("\n=== Lease-driven failover, executed (Tcp loopback, Session API) ===");
    let spec = RunSpec::synthetic()
        .actors(3)
        .steps(3)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(512)
        .deterministic()
        .wall_leases();
    let layout = || ModelLayout::transformer("syn-pool", 256, 64, 2, 128);
    let comp = || SyntheticCompute::new(16, 8, 64);
    let baseline = Session::start_with_compute(&spec.clone().build()?, layout(), comp())?.join()?;
    let killed = spec.transport(Backend::Tcp(TcpConfig {
        streams: 2,
        bits_per_s: None,
        kill: Some(KillSpec { actor: 2, at_version: 1, mode: KillMode::Crash }),
    }));
    let mut session = Session::start_with_compute(&killed.build()?, layout(), comp())?;
    let report = loop {
        match session.recv() {
            Some(Event::Failover { actor, requeued }) => {
                println!("actor {actor} crashed; {requeued} prompt(s) re-leased to survivors")
            }
            Some(Event::Finished(r)) => break r,
            Some(_) => {}
            None => anyhow::bail!("session ended without a report"),
        }
    };
    let same = report.steps.last().unwrap().policy_checksum
        == baseline.steps.last().unwrap().policy_checksum;
    println!(
        "failovers {} | final checksum {} | bit-identical to no-failure baseline: {same}",
        report.failovers,
        &report.steps.last().unwrap().checksum_hex()[..12],
    );
    Ok(())
}
