//! Geo-distributed deployment study: the paper's §7.5 scenario as a
//! simulated campaign — actors spread across 1-4 continents, all four
//! systems, with a live Gantt of the winning configuration — plus the
//! *real* pipelined runtime on the 4-region relay tree, driven through
//! the Session API's typed event stream.
//!
//! ```bash
//! cargo run --release --example geo_distributed [-- --model qwen3-8b --steps 7]
//! ```

use sparrowrl::config::{self, regions, GpuClass};
use sparrowrl::data::Benchmark;
use sparrowrl::delta::ModelLayout;
use sparrowrl::metrics::SpanKind;
use sparrowrl::rt::SyntheticCompute;
use sparrowrl::session::{Event, RunSpec, Session};
use sparrowrl::sim::driver::{run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::util::cli::Args;
use sparrowrl::util::{fmt_bytes, fmt_secs};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "qwen3-8b");
    let steps = args.parse_or("steps", 7u64);
    let model = config::model(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;

    println!("=== SparrowRL geo-distributed study: {model_name}, {steps} steps ===\n");
    let sites = [
        ("1 region  (Canada)", vec![regions::CANADA]),
        ("2 regions (+Japan)", vec![regions::CANADA, regions::JAPAN]),
        ("3 regions (+Netherlands)", vec![regions::CANADA, regions::JAPAN, regions::NETHERLANDS]),
        (
            "4 regions (+Iceland)",
            vec![regions::CANADA, regions::JAPAN, regions::NETHERLANDS, regions::ICELAND],
        ),
    ];
    println!(
        "{:<28} {:>14} {:>14} {:>14} {:>10}",
        "deployment", "SparrowRL", "PrimeRL-Full", "Ideal-1DC", "Sp/Full"
    );
    for (label, regs) in &sites {
        // 8 A100s spread round-robin across the regions.
        let mut fleet: Vec<RegionSpec> =
            regs.iter().map(|r| RegionSpec::new(*r, vec![])).collect();
        let n_regions = fleet.len();
        for i in 0..8 {
            fleet[i % n_regions].gpus.push(GpuClass::A100);
        }
        let thr = |sys: System| {
            let mut cfg =
                SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, sys, fleet.clone());
            cfg.steps = steps;
            run(&cfg).throughput()
        };
        let sp = thr(System::Sparrow);
        let full = thr(System::PrimeRlFull);
        let ideal = thr(System::IdealSingleDc);
        println!(
            "{:<28} {:>10.0} t/s {:>10.0} t/s {:>10.0} t/s {:>9.1}x",
            label, sp, full, ideal,
            sp / full
        );
    }

    // Timeline of the 4-region SparrowRL run.
    let mut fleet: Vec<RegionSpec> = [
        regions::CANADA,
        regions::JAPAN,
        regions::NETHERLANDS,
        regions::ICELAND,
    ]
    .iter()
    .map(|r| RegionSpec::new(*r, vec![GpuClass::A100, GpuClass::A100]))
    .collect();
    fleet[0].gpus.push(GpuClass::A100);
    let mut cfg =
        SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, System::Sparrow, fleet);
    cfg.steps = 5;
    let r = run(&cfg);
    println!(
        "\n4-region SparrowRL timeline ({} steps, total {}; delta payload {}/step):",
        cfg.steps,
        fmt_secs(r.total_time),
        fmt_bytes(r.payload_bytes())
    );
    print!("{}", r.timeline.ascii_gantt(96));

    // The same 4-region tree for real: the pipelined executor on the
    // synthetic engine, hub -> regional relay -> peers, observed live
    // through the Session event stream. `wan("wan-4")` derives the
    // fleet, the relay tree, and the pipelined coercion inside build().
    let plan = RunSpec::synthetic()
        .wan("wan-4")
        .steps(4)
        .sft_steps(0)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .build()?;
    println!("\nlive runtime on wan-4 ({} actors):", plan.config().n_actors);
    for note in plan.notes() {
        println!("  note: {note}");
    }
    let layout = ModelLayout::transformer("syn-geo", 512, 128, 2, 256);
    let comp = SyntheticCompute::new(16, 8, 64)
        .with_delays(Duration::from_millis(6), Duration::from_millis(5));
    let mut session = Session::start_with_compute(&plan, layout, comp)?;
    let report = loop {
        match session.recv() {
            Some(Event::DeltaStreamed { version, payload_bytes, stripes }) => println!(
                "  D_v{version}: {} in {stripes} segments to every region relay",
                fmt_bytes(payload_bytes),
            ),
            Some(Event::Finished(r)) => break r,
            Some(_) => {}
            None => anyhow::bail!("session ended without a report"),
        }
    };
    println!(
        "  {} versions committed bit-exact on 4 continents; wall {:.2}s, hidden sync {:.0}%",
        report.final_version,
        report.wall_s,
        report.timeline.overlap_ratio("trainer", &[SpanKind::Train, SpanKind::Extract]) * 100.0,
    );
    Ok(())
}
