//! Geo-distributed deployment study: the paper's §7.5 scenario as a
//! simulated campaign — actors spread across 1-4 continents, all four
//! systems, with a live Gantt of the winning configuration.
//!
//! ```bash
//! cargo run --release --example geo_distributed [-- --model qwen3-8b --steps 7]
//! ```

use sparrowrl::config::{self, regions, GpuClass};
use sparrowrl::data::Benchmark;
use sparrowrl::sim::driver::{run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::util::cli::Args;
use sparrowrl::util::{fmt_bytes, fmt_secs};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "qwen3-8b");
    let steps = args.parse_or("steps", 7u64);
    let model = config::model(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;

    println!("=== SparrowRL geo-distributed study: {model_name}, {steps} steps ===\n");
    let sites = [
        ("1 region  (Canada)", vec![regions::CANADA]),
        ("2 regions (+Japan)", vec![regions::CANADA, regions::JAPAN]),
        ("3 regions (+Netherlands)", vec![regions::CANADA, regions::JAPAN, regions::NETHERLANDS]),
        (
            "4 regions (+Iceland)",
            vec![regions::CANADA, regions::JAPAN, regions::NETHERLANDS, regions::ICELAND],
        ),
    ];
    println!(
        "{:<28} {:>14} {:>14} {:>14} {:>10}",
        "deployment", "SparrowRL", "PrimeRL-Full", "Ideal-1DC", "Sp/Full"
    );
    for (label, regs) in &sites {
        // 8 A100s spread round-robin across the regions.
        let mut fleet: Vec<RegionSpec> =
            regs.iter().map(|r| RegionSpec::new(*r, vec![])).collect();
        let n_regions = fleet.len();
        for i in 0..8 {
            fleet[i % n_regions].gpus.push(GpuClass::A100);
        }
        let thr = |sys: System| {
            let mut cfg =
                SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, sys, fleet.clone());
            cfg.steps = steps;
            run(&cfg).throughput()
        };
        let sp = thr(System::Sparrow);
        let full = thr(System::PrimeRlFull);
        let ideal = thr(System::IdealSingleDc);
        println!(
            "{:<28} {:>10.0} t/s {:>10.0} t/s {:>10.0} t/s {:>9.1}x",
            label, sp, full, ideal,
            sp / full
        );
    }

    // Timeline of the 4-region SparrowRL run.
    let mut fleet: Vec<RegionSpec> = [
        regions::CANADA,
        regions::JAPAN,
        regions::NETHERLANDS,
        regions::ICELAND,
    ]
    .iter()
    .map(|r| RegionSpec::new(*r, vec![GpuClass::A100, GpuClass::A100]))
    .collect();
    fleet[0].gpus.push(GpuClass::A100);
    let mut cfg =
        SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, System::Sparrow, fleet);
    cfg.steps = 5;
    let r = run(&cfg);
    println!(
        "\n4-region SparrowRL timeline ({} steps, total {}; delta payload {}/step):",
        cfg.steps,
        fmt_secs(r.total_time),
        fmt_bytes(r.payload_bytes())
    );
    print!("{}", r.timeline.ascii_gantt(96));
    Ok(())
}
