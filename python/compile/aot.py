"""AOT pipeline: lower the L2/L1 JAX functions to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowering uses ``return_tuple=True``
so the rust loader unwraps one output tuple.

Artifacts per model (under ``artifacts/``):
  {model}_policy_fwd.hlo.txt   bf16 params x7, tokens[Bg,T] -> logits
  {model}_train_step.hlo.txt   f32 params/m/v x7, tokens[Bt,T], mask, adv,
                               lr, t -> params'/m'/v' x7, loss
  {model}_delta_diff.hlo.txt   bf16 old x7, new x7 -> mask[N] i8, nnz i32
  manifest.txt                 shapes/hparams, key=value per line

Usage: python -m compile.aot --out ../artifacts [--models a,b] [--force]
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .presets import PRESETS, TENSOR_ORDER, tensor_shapes

DEFAULT_MODELS = ["sparrow-xs", "sparrow-s"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs(preset, dtype):
    return tuple(
        jax.ShapeDtypeStruct(tensor_shapes(preset)[n], dtype) for n in TENSOR_ORDER
    )


def lower_policy_fwd(preset):
    p_spec = specs(preset, jnp.bfloat16)
    tok = jax.ShapeDtypeStruct((preset.b_gen, preset.max_seq), jnp.int32)

    def fn(*args):
        params = args[:7]
        tokens = args[7]
        return (M.policy_fwd(params, tokens, preset),)

    return jax.jit(fn).lower(*p_spec, tok)


def lower_train_step(preset):
    p_spec = specs(preset, jnp.float32)
    bt, t = preset.b_train, preset.max_seq
    tok = jax.ShapeDtypeStruct((bt, t), jnp.int32)
    msk = jax.ShapeDtypeStruct((bt, t), jnp.float32)
    adv = jax.ShapeDtypeStruct((bt,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(*args):
        params, m, v = args[0:7], args[7:14], args[14:21]
        tokens, mask, advs, lr, step_t = args[21:26]
        new_p, new_m, new_v, loss = M.train_step(
            params, m, v, tokens, mask, advs, lr, step_t, preset
        )
        return (*new_p, *new_m, *new_v, loss)

    return jax.jit(fn).lower(
        *p_spec, *p_spec, *p_spec, tok, msk, adv, scalar, scalar
    )


def lower_delta_diff(preset):
    p_spec = specs(preset, jnp.bfloat16)

    def fn(*args):
        old, new = args[:7], args[7:14]
        mask, nnz = M.delta_diff(old, new)
        return (mask, nnz)

    return jax.jit(fn).lower(*p_spec, *p_spec)


def manifest_lines(preset):
    shp = tensor_shapes(preset)
    lines = [
        f"model={preset.name}",
        f"vocab={preset.vocab}",
        f"d_model={preset.d_model}",
        f"n_layers={preset.n_layers}",
        f"n_heads={preset.n_heads}",
        f"d_ff={preset.d_ff}",
        f"max_seq={preset.max_seq}",
        f"b_gen={preset.b_gen}",
        f"b_train={preset.b_train}",
        f"param_count={preset.param_count()}",
    ]
    for n in TENSOR_ORDER:
        lines.append(f"shape.{n}={','.join(str(d) for d in shp[n])}")
    return lines


def inputs_fingerprint() -> str:
    """Hash of the compile-path sources; drives incremental rebuilds."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def build(out_dir: str, models, force: bool) -> int:
    os.makedirs(out_dir, exist_ok=True)
    stamp_path = os.path.join(out_dir, "STAMP")
    fp = inputs_fingerprint() + ":" + ",".join(models)
    if not force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read().strip() == fp:
                print(f"artifacts up to date ({fp})")
                return 0
    manifest = [f"fingerprint={fp}"]
    for name in models:
        preset = PRESETS[name]
        print(f"[{name}] lowering policy_fwd ...", flush=True)
        jobs = [
            ("policy_fwd", lower_policy_fwd),
            ("train_step", lower_train_step),
            ("delta_diff", lower_delta_diff),
        ]
        for kind, fn in jobs:
            print(f"[{name}] lowering {kind} ...", flush=True)
            text = to_hlo_text(fn(preset))
            path = os.path.join(out_dir, f"{name}_{kind}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"[{name}] wrote {path} ({len(text) / 1e6:.2f} MB)")
        manifest.extend(manifest_lines(preset))
        manifest.append("")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    with open(stamp_path, "w") as f:
        f.write(fp)
    print(f"manifest + stamp written to {out_dir}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    models = [m for m in args.models.split(",") if m]
    for m in models:
        if m not in PRESETS:
            print(f"unknown model {m!r}; known: {sorted(PRESETS)}", file=sys.stderr)
            return 2
    return build(args.out, models, args.force)


if __name__ == "__main__":
    sys.exit(main())
