"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas (interpret-mode) kernels match these
references to float tolerance (attention) or exactly (delta-diff).
"""

import jax.numpy as jnp


def causal_attention_ref(q, k, v):
    """Reference multi-head causal attention.

    q, k, v: [B, H, T, Dh] float32. Returns [B, H, T, Dh].
    """
    *_, t, dh = q.shape
    scale = 1.0 / (dh**0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None, :, :], scores, -jnp.inf)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def delta_mask_ref(old_bits, new_bits):
    """Reference bitwise-change mask.

    old_bits, new_bits: [N] uint16 (bf16 bit patterns). Returns [N] int8
    with 1 where the stored pattern changed.
    """
    return (old_bits != new_bits).astype(jnp.int8)
