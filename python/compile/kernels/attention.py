"""Pallas causal-attention kernel — the rollout-generation compute hot spot.

Hardware adaptation (DESIGN.md §5): the CUDA flash-attention idiom (one
threadblock per (batch, head), K/V tiles staged through shared memory) maps
to TPU as one *grid point* per (batch, head) with the Q/K/V tiles resident
in VMEM and the score matmuls shaped for the MXU. At the sequence lengths
this repo serves (T <= 128, Dh <= 64) a whole (T, Dh) tile fits VMEM
comfortably (3 inputs + scores + output: 4*T*Dh + T*T floats ~ 192 KiB at
T=128, Dh=64, far under the ~16 MiB budget), so the kernel uses a
single-tile layout with a stable softmax; BlockSpec carries the HBM->VMEM
schedule that CUDA expresses with threadblocks/shared memory.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both pytest and the
rust runtime execute. Real-TPU performance is *estimated* from the VMEM
footprint and MXU-shape analysis in EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    """One (batch, head) tile: scores -> causal mask -> softmax -> values."""
    q = q_ref[0, 0]  # [T, Dh]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    t, dh = q.shape
    scale = 1.0 / (dh**0.5)
    # MXU-shaped matmul: [T, Dh] x [Dh, T] -> [T, T].
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Causal mask from 2-D iotas (TPU requires >=2-D iota).
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(rows >= cols, scores, neg)
    # Numerically-stable softmax on the VPU lanes.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(w, v, preferred_element_type=jnp.float32)


def causal_attention(q, k, v):
    """Multi-head causal attention via Pallas.

    q, k, v: [B, H, T, Dh] float32 -> [B, H, T, Dh] float32.

    Grid: one program per (batch, head); BlockSpec stages that head's
    (T, Dh) Q/K/V tiles into VMEM.
    """
    b, h, t, dh = q.shape
    spec = pl.BlockSpec((1, 1, t, dh), lambda i, j: (i, j, 0, 0))
    kernel = pl.pallas_call(
        _attn_kernel,
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), jnp.float32),
        interpret=True,
    )
    return kernel(q, k, v)


def vmem_footprint_bytes(t: int, dh: int) -> int:
    """Estimated VMEM bytes per grid point (EXPERIMENTS.md §Perf)."""
    tiles = 4 * t * dh  # q, k, v, o
    scores = t * t * 2  # scores + weights buffers
    return 4 * (tiles + scores)
