"""Pallas delta-extraction kernel: bitwise change mask over bf16 storage.

The paper's per-step CPU hot spot is scanning parameters for changed
elements (§5.2: ~5 s for a 16 GB model). On TPU this compare is a pure VPU
lane operation; the kernel tiles the flattened bf16 bit-pattern arrays
through VMEM in (8, 128)-lane-aligned blocks and emits an int8 change mask.
The host (rust) then compacts mask -> (index, value) pairs, mirroring the
paper's CPU-side encode stage.

Comparison is on *bit patterns* (uint16), not float values, so NaN payload
changes and -0.0/+0.0 flips are captured — "the delta is whatever changed
in storage", which is what lossless replication requires.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-aligned tile: 8 sublanes x 128 lanes x 8 rows.
BLOCK = 8 * 128 * 8


def _diff_kernel(old_ref, new_ref, mask_ref):
    mask_ref[...] = (old_ref[...] != new_ref[...]).astype(jnp.int8)


def delta_mask(old_bits, new_bits, block: int = BLOCK):
    """Elementwise change mask.

    old_bits, new_bits: [N] uint16 (bf16 bit patterns), N padded by the
    caller to a multiple of `block`. Returns [N] int8.
    """
    (n,) = old_bits.shape
    assert n % block == 0, f"caller must pad to a multiple of {block}, got {n}"
    spec = pl.BlockSpec((block,), lambda i: (i,))
    kernel = pl.pallas_call(
        _diff_kernel,
        grid=(n // block,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int8),
        interpret=True,
    )
    return kernel(old_bits, new_bits)


def pad_to_block(x, block: int = BLOCK, fill=0):
    """Pad a 1-D array up to the next multiple of `block`."""
    (n,) = x.shape
    rem = (-n) % block
    if rem == 0:
        return x
    return jnp.concatenate([x, jnp.full((rem,), fill, dtype=x.dtype)])


def delta_mask_padded(old_bits, new_bits, block: int = BLOCK):
    """Mask for unpadded inputs; pads both sides with equal fills so the
    padding never reports a change, then trims."""
    (n,) = old_bits.shape
    om = pad_to_block(old_bits, block)
    nm = pad_to_block(new_bits, block)
    return delta_mask(om, nm, block)[:n]
