"""L2: the transformer policy and its RL train step, in JAX.

Build-time only — this module is lowered once by ``aot.py`` into HLO text
artifacts that the rust coordinator executes through PJRT; Python never
runs on the request path.

Parameters are a 7-tuple of fused tensors in ``presets.TENSOR_ORDER``
(matching rust's ``ModelLayout::transformer`` exactly):

    embed [V,D], final_norm [D], norms [L,2,D], qkv_proj [L,D,3D],
    o_proj [L,D,D], gate_up_proj [L,D,2F], down_proj [L,F,D]

Three entry points get lowered:

* ``policy_fwd``  — bf16 params + tokens -> logits. Rollout actors call
  this in the generation loop; attention runs through the Pallas kernel.
* ``train_step``  — f32 master params + Adam state + (tokens, mask, adv)
  -> updated params/state + loss. Algorithm-agnostic: GRPO/RLOO/OPO differ
  only in how the coordinator computes ``adv`` (rust, trainer/algorithms).
  With ``adv = 1`` and a full mask this is supervised NLL — the same
  artifact pretrains and RL-finetunes.
* ``delta_diff``  — two bf16 snapshots -> change mask (Pallas kernel).

The sparsity mechanism (paper §3) is reproduced, not faked: the Trainer
keeps f32 master weights, actors hold bf16 policies, and with post-training
learning rates (~1e-6) most Adam updates are below the bf16 ulp of their
element — only ~1% of stored values change per step.
"""

import jax
import jax.numpy as jnp

from .kernels.attention import causal_attention
from .kernels.delta_diff import delta_mask_padded
from .kernels.ref import causal_attention_ref
from .presets import PRESETS, TENSOR_ORDER, ModelPreset, tensor_shapes

EPS_NORM = 1e-6
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(preset: ModelPreset, seed: int = 0):
    """Gaussian init (sigma=0.02 except norms at 1.0), f32 master weights."""
    shapes = tensor_shapes(preset)
    key = jax.random.PRNGKey(seed)
    out = []
    for name in TENSOR_ORDER:
        key, sub = jax.random.split(key)
        if name in ("final_norm", "norms"):
            out.append(jnp.ones(shapes[name], jnp.float32))
        else:
            out.append(jax.random.normal(sub, shapes[name], jnp.float32) * 0.02)
    return tuple(out)


def to_policy(params):
    """Quantize master weights to the bf16 policy actors hold."""
    return tuple(p.astype(jnp.bfloat16) for p in params)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS_NORM)


def forward(params, tokens, preset: ModelPreset, use_pallas: bool):
    """Transformer forward: tokens [B, T] int32 -> logits [B, T, V] f32.

    ``use_pallas`` selects the Pallas attention kernel (inference path) or
    the jnp reference (training path, which must be differentiable).
    """
    embed, final_norm, norms, qkv_proj, o_proj, gate_up_proj, down_proj = (
        p.astype(jnp.float32) for p in params
    )
    b, t = tokens.shape
    h_heads, dh = preset.n_heads, preset.head_dim
    x = embed[tokens]  # [B, T, D]
    attn_fn = causal_attention if use_pallas else causal_attention_ref
    for l in range(preset.n_layers):
        # Attention block (fused QKV, paper Fig 6 layout).
        h = _rmsnorm(x, norms[l, 0])
        qkv = h @ qkv_proj[l]  # [B, T, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return z.reshape(b, t, h_heads, dh).transpose(0, 2, 1, 3)

        attn = attn_fn(heads(q), heads(k), heads(v))  # [B, H, T, Dh]
        attn = attn.transpose(0, 2, 1, 3).reshape(b, t, -1)
        x = x + attn @ o_proj[l]
        # SwiGLU MLP (fused Gate|Up).
        h = _rmsnorm(x, norms[l, 1])
        gu = h @ gate_up_proj[l]
        g, u = jnp.split(gu, 2, axis=-1)
        x = x + (jax.nn.silu(g) * u) @ down_proj[l]
    x = _rmsnorm(x, final_norm)
    return x @ embed.T  # weight-tied head, [B, T, V]


def policy_fwd(params_bf16, tokens, preset: ModelPreset):
    """Inference entry point (lowered with Pallas attention)."""
    return forward(params_bf16, tokens, preset, use_pallas=True)


# --------------------------------------------------------------------------
# Training step
# --------------------------------------------------------------------------

def _pg_loss(params, tokens, gen_mask, adv, preset: ModelPreset):
    """Token-level policy-gradient surrogate.

    tokens   [B, T] int32   — prompt + generated (padded)
    gen_mask [B, T] f32     — 1 on positions whose *prediction* is scored
                              (i.e. mask[t] scores logits at t-1)
    adv      [B]    f32     — per-sequence advantage (1.0 => supervised NLL)
    """
    logits = forward(params, tokens, preset, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # Position t's logits predict token t+1.
    pred = logp[:, :-1, :]
    tgt = tokens[:, 1:]
    tgt_logp = jnp.take_along_axis(pred, tgt[:, :, None], axis=-1)[..., 0]
    w = gen_mask[:, 1:] * adv[:, None]
    denom = jnp.maximum(gen_mask[:, 1:].sum(), 1.0)
    return -(w * tgt_logp).sum() / denom


def train_step(params, m_state, v_state, tokens, gen_mask, adv, lr, step_t,
               preset: ModelPreset):
    """One Adam update on the policy-gradient surrogate.

    Returns (new_params, new_m, new_v, loss). ``step_t`` is the 1-based
    Adam timestep (f32) for bias correction.
    """
    loss, grads = jax.value_and_grad(
        lambda p: _pg_loss(p, tokens, gen_mask, adv, preset)
    )(params)
    b1t = 1.0 - ADAM_B1**step_t
    b2t = 1.0 - ADAM_B2**step_t
    new_params, new_m, new_v = [], [], []
    for p, m, v, g in zip(params, m_state, v_state, grads):
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m2 / b1t
        vhat = v2 / b2t
        new_params.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_params), tuple(new_m), tuple(new_v), loss


# --------------------------------------------------------------------------
# Delta diff (Pallas extraction kernel over the full fused layout)
# --------------------------------------------------------------------------

def delta_diff(old_policy, new_policy):
    """Concatenated bitwise change mask over all fused tensors.

    old_policy/new_policy: bf16 tuples in TENSOR_ORDER. Returns
    (mask [N] int8, nnz i32) where N = total parameter count.
    """
    old_bits = jnp.concatenate(
        [jax.lax.bitcast_convert_type(p, jnp.uint16).reshape(-1) for p in old_policy]
    )
    new_bits = jnp.concatenate(
        [jax.lax.bitcast_convert_type(p, jnp.uint16).reshape(-1) for p in new_policy]
    )
    mask = delta_mask_padded(old_bits, new_bits)
    return mask, mask.astype(jnp.int32).sum()


# --------------------------------------------------------------------------
# Convenience: preset lookup
# --------------------------------------------------------------------------

def preset(name: str) -> ModelPreset:
    return PRESETS[name]
