"""Model presets shared between the L2 JAX model and the L3 rust runtime.

These MUST stay in lock-step with ``rust/src/config/presets.rs`` — the rust
side validates artifact shapes against the same table (via the emitted
manifest) before serving them.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelPreset:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    # AOT batch shapes (fixed at lowering time; the coordinator pads).
    b_gen: int
    b_train: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        v, d, l, f = self.vocab, self.d_model, self.n_layers, self.d_ff
        return v * d + d + l * (2 * d) + l * d * 3 * d + l * d * d + l * d * 2 * f + l * f * d


PRESETS = {
    "sparrow-xs": ModelPreset("sparrow-xs", 256, 64, 2, 4, 256, 64, 8, 32),
    "sparrow-s": ModelPreset("sparrow-s", 512, 128, 4, 8, 512, 64, 8, 32),
    "sparrow-m": ModelPreset("sparrow-m", 1024, 256, 6, 8, 1024, 96, 8, 16),
    "sparrow-l": ModelPreset("sparrow-l", 2048, 512, 8, 16, 2048, 128, 4, 8),
    "sparrow-xl": ModelPreset("sparrow-xl", 4096, 768, 12, 12, 3072, 128, 4, 8),
}

# Fused tensor order — identical to rust ModelLayout::transformer.
TENSOR_ORDER = (
    "embed",
    "final_norm",
    "norms",
    "qkv_proj",
    "o_proj",
    "gate_up_proj",
    "down_proj",
)


def tensor_shapes(p: ModelPreset) -> dict:
    """Fused tensor shapes, matching rust ModelLayout::transformer."""
    v, d, l, f = p.vocab, p.d_model, p.n_layers, p.d_ff
    return {
        "embed": (v, d),
        "final_norm": (d,),
        "norms": (l, 2, d),
        "qkv_proj": (l, d, 3 * d),
        "o_proj": (l, d, d),
        "gate_up_proj": (l, d, 2 * f),
        "down_proj": (l, f, d),
    }
