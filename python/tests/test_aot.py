"""AOT pipeline: lowering produces parseable HLO text with the expected
I/O arity, and the incremental stamp machinery behaves."""

import os
import tempfile

from compile import aot
from compile.presets import PRESETS


def test_hlo_text_emitted_for_policy_fwd():
    text = aot.to_hlo_text(aot.lower_policy_fwd(PRESETS["sparrow-xs"]))
    assert "HloModule" in text
    assert "ENTRY" in text
    # bf16 params appear in the signature.
    assert "bf16" in text


def test_train_step_has_26_inputs_22_outputs():
    p = PRESETS["sparrow-xs"]
    text = aot.to_hlo_text(aot.lower_train_step(p))
    # 7 params + 7 m + 7 v + tokens + mask + adv + lr + t = 26 parameters.
    count = text.count("parameter(")
    assert count >= 26, f"expected >=26 parameter instructions, got {count}"


def test_build_writes_artifacts_and_manifest(tmp_path=None):
    out = tempfile.mkdtemp(prefix="sprw-aot-")
    rc = aot.build(out, ["sparrow-xs"], force=True)
    assert rc == 0
    names = set(os.listdir(out))
    assert {"manifest.txt", "STAMP"} <= names
    for kind in ("policy_fwd", "train_step", "delta_diff"):
        assert f"sparrow-xs_{kind}.hlo.txt" in names
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "model=sparrow-xs" in manifest
    assert f"param_count={PRESETS['sparrow-xs'].param_count()}" in manifest


def test_build_is_incremental():
    out = tempfile.mkdtemp(prefix="sprw-aot-inc-")
    assert aot.build(out, ["sparrow-xs"], force=False) == 0
    mtime = os.path.getmtime(os.path.join(out, "sparrow-xs_policy_fwd.hlo.txt"))
    assert aot.build(out, ["sparrow-xs"], force=False) == 0
    assert os.path.getmtime(os.path.join(out, "sparrow-xs_policy_fwd.hlo.txt")) == mtime
