"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; attention must match the reference to float
tolerance, delta-diff must match the bitwise oracle exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import causal_attention, vmem_footprint_bytes
from compile.kernels.delta_diff import BLOCK, delta_mask, delta_mask_padded, pad_to_block
from compile.kernels.ref import causal_attention_ref, delta_mask_ref


def rand_qkv(rng, b, h, t, dh):
    shape = (b, h, t, dh)
    return (
        jnp.asarray(rng.standard_normal(shape), jnp.float32),
        jnp.asarray(rng.standard_normal(shape), jnp.float32),
        jnp.asarray(rng.standard_normal(shape), jnp.float32),
    )


class TestAttention:
    def test_matches_reference_basic(self):
        rng = np.random.default_rng(0)
        q, k, v = rand_qkv(rng, 2, 4, 16, 8)
        np.testing.assert_allclose(
            causal_attention(q, k, v), causal_attention_ref(q, k, v),
            rtol=1e-5, atol=1e-5,
        )

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        t=st.sampled_from([1, 2, 5, 16, 33, 64]),
        dh=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_reference_swept(self, b, h, t, dh, seed):
        rng = np.random.default_rng(seed)
        q, k, v = rand_qkv(rng, b, h, t, dh)
        np.testing.assert_allclose(
            causal_attention(q, k, v), causal_attention_ref(q, k, v),
            rtol=2e-5, atol=2e-5,
        )

    def test_causality(self):
        """Changing future K/V must not affect earlier outputs."""
        rng = np.random.default_rng(1)
        q, k, v = rand_qkv(rng, 1, 2, 8, 4)
        out = causal_attention(q, k, v)
        k2 = k.at[:, :, -1, :].set(99.0)
        v2 = v.at[:, :, -1, :].set(-99.0)
        out2 = causal_attention(q, k2, v2)
        np.testing.assert_allclose(out[:, :, :-1], out2[:, :, :-1], rtol=1e-6)
        assert not np.allclose(out[:, :, -1], out2[:, :, -1])

    def test_softmax_rows_are_convex_combinations(self):
        """Each output must lie within the [min, max] envelope of V."""
        rng = np.random.default_rng(2)
        q, k, v = rand_qkv(rng, 1, 1, 12, 4)
        out = np.asarray(causal_attention(q, k, v))
        vnp = np.asarray(v)
        for t in range(12):
            lo = vnp[0, 0, : t + 1].min(axis=0) - 1e-5
            hi = vnp[0, 0, : t + 1].max(axis=0) + 1e-5
            assert (out[0, 0, t] >= lo).all() and (out[0, 0, t] <= hi).all()

    def test_first_position_is_v0(self):
        rng = np.random.default_rng(3)
        q, k, v = rand_qkv(rng, 2, 2, 6, 8)
        out = causal_attention(q, k, v)
        np.testing.assert_allclose(out[:, :, 0], v[:, :, 0], rtol=1e-6)

    def test_vmem_footprint_under_budget(self):
        # Largest served config: T=128, Dh=64.
        assert vmem_footprint_bytes(128, 64) < 16 * 1024 * 1024


class TestDeltaDiff:
    def test_matches_reference_exactly(self):
        rng = np.random.default_rng(0)
        old = jnp.asarray(rng.integers(0, 2**16, BLOCK, dtype=np.uint16))
        new = old.at[::7].set(0)
        got = delta_mask(old, new)
        want = delta_mask_ref(old, new)
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, 4 * BLOCK), seed=st.integers(0, 2**16))
    def test_padded_any_length(self, n, seed):
        rng = np.random.default_rng(seed)
        old = jnp.asarray(rng.integers(0, 2**16, n, dtype=np.uint16))
        flips = rng.integers(0, 2, n).astype(np.uint16)
        new = old ^ jnp.asarray(flips)
        got = delta_mask_padded(old, new)
        np.testing.assert_array_equal(got, delta_mask_ref(old, new))
        assert got.shape == (n,)

    def test_identical_inputs_give_zero_mask(self):
        x = jnp.arange(BLOCK, dtype=jnp.uint16)
        assert int(delta_mask(x, x).sum()) == 0

    def test_pad_to_block(self):
        x = jnp.ones((10,), jnp.uint16)
        y = pad_to_block(x, 16)
        assert y.shape == (16,)
        np.testing.assert_array_equal(y[:10], x)
        assert int(y[10:].sum()) == 0
        z = pad_to_block(jnp.ones((16,), jnp.uint16), 16)
        assert z.shape == (16,)

    def test_nan_payload_changes_detected(self):
        """bf16 NaN bit-pattern changes are storage changes."""
        old = pad_to_block(jnp.asarray([0x7FC0], jnp.uint16))  # quiet NaN
        new = pad_to_block(jnp.asarray([0x7FC1], jnp.uint16))  # other NaN
        assert int(delta_mask(old, new)[0]) == 1
