"""L2 correctness: transformer shapes, invariances, training dynamics, and
the bf16 update-sparsity mechanism the whole paper rests on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.presets import PRESETS, TENSOR_ORDER, tensor_shapes

XS = PRESETS["sparrow-xs"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(XS, seed=0)


def rand_tokens(rng, b, t, vocab):
    return jnp.asarray(rng.integers(0, vocab, (b, t)), jnp.int32)


class TestForward:
    def test_shapes(self, params):
        rng = np.random.default_rng(0)
        tokens = rand_tokens(rng, 3, 16, XS.vocab)
        logits = M.forward(params, tokens, XS, use_pallas=False)
        assert logits.shape == (3, 16, XS.vocab)
        assert logits.dtype == jnp.float32

    def test_pallas_and_ref_paths_agree(self, params):
        """policy_fwd (Pallas attention) == training fwd (jnp attention)."""
        rng = np.random.default_rng(1)
        tokens = rand_tokens(rng, 2, XS.max_seq, XS.vocab)
        ref = M.forward(params, tokens, XS, use_pallas=False)
        pal = M.forward(params, tokens, XS, use_pallas=True)
        np.testing.assert_allclose(pal, ref, rtol=3e-5, atol=3e-5)

    def test_causality_of_full_model(self, params):
        rng = np.random.default_rng(2)
        tokens = rand_tokens(rng, 1, 12, XS.vocab)
        logits = M.forward(params, tokens, XS, use_pallas=False)
        tokens2 = tokens.at[0, -1].set((int(tokens[0, -1]) + 1) % XS.vocab)
        logits2 = M.forward(params, tokens2, XS, use_pallas=False)
        np.testing.assert_allclose(logits[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5)

    def test_policy_fwd_accepts_bf16(self, params):
        rng = np.random.default_rng(3)
        tokens = rand_tokens(rng, XS.b_gen, XS.max_seq, XS.vocab)
        logits = M.policy_fwd(M.to_policy(params), tokens, XS)
        assert logits.shape == (XS.b_gen, XS.max_seq, XS.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_param_shapes_match_preset_table(self, params):
        shapes = tensor_shapes(XS)
        for name, p in zip(TENSOR_ORDER, params):
            assert p.shape == shapes[name], name
        assert XS.param_count() == sum(int(np.prod(p.shape)) for p in params)


class TestTrainStep:
    def _batch(self, rng, b=4, t=16):
        tokens = rand_tokens(rng, b, t, XS.vocab)
        mask = jnp.ones((b, t), jnp.float32)
        adv = jnp.ones((b,), jnp.float32)
        return tokens, mask, adv

    def test_supervised_loss_decreases(self, params):
        """adv=1 + full mask = NLL training; loss must drop on a fixed batch."""
        rng = np.random.default_rng(4)
        tokens, mask, adv = self._batch(rng)
        zeros = tuple(jnp.zeros_like(p) for p in params)
        p, m, v = params, zeros, zeros
        losses = []
        for step in range(8):
            p, m, v, loss = M.train_step(
                p, m, v, tokens, mask, adv, 1e-2, float(step + 1), XS
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.9, losses

    def test_zero_advantage_freezes_params(self, params):
        rng = np.random.default_rng(5)
        tokens, mask, _ = self._batch(rng)
        zeros = tuple(jnp.zeros_like(p) for p in params)
        adv = jnp.zeros((4,), jnp.float32)
        new_p, _, _, loss = M.train_step(
            params, zeros, zeros, tokens, mask, adv, 1e-2, 1.0, XS
        )
        assert float(loss) == 0.0
        for a, b in zip(params, new_p):
            np.testing.assert_array_equal(a, b)

    def test_negative_advantage_pushes_logp_down(self, params):
        rng = np.random.default_rng(6)
        tokens, mask, _ = self._batch(rng, b=2)
        zeros = tuple(jnp.zeros_like(p) for p in params)

        def seq_logp(p):
            logits = M.forward(p, tokens, XS, use_pallas=False)
            lp = jax.nn.log_softmax(logits, axis=-1)
            t = jnp.take_along_axis(lp[:, :-1], tokens[:, 1:, None], axis=-1)
            return float(t.sum())

        before = seq_logp(params)
        adv = -jnp.ones((2,), jnp.float32)
        new_p, _, _, _ = M.train_step(
            params, zeros, zeros, tokens, mask, adv, 1e-2, 1.0, XS
        )
        after = seq_logp(new_p)
        assert after < before

    def test_mask_restricts_gradient_to_generated_positions(self, params):
        """Tokens outside the mask must not influence the loss value."""
        rng = np.random.default_rng(7)
        b, t = 2, 16
        tokens = rand_tokens(rng, b, t, XS.vocab)
        mask = jnp.zeros((b, t), jnp.float32).at[:, 8:].set(1.0)
        adv = jnp.ones((b,), jnp.float32)
        loss1 = M._pg_loss(params, tokens, mask, adv, XS)
        # Perturb a masked-out (prompt) token whose prediction is unscored.
        tokens2 = tokens.at[0, 3].set((int(tokens[0, 3]) + 1) % XS.vocab)
        loss2 = M._pg_loss(params, tokens2, mask, adv, XS)
        # Prompt token still feeds attention context, so losses may differ,
        # but the scored positions are 8.. => changing token 3's *target*
        # role must not matter. Verify via the mask itself:
        mask_zero = jnp.zeros((b, t), jnp.float32)
        assert float(M._pg_loss(params, tokens, mask_zero, adv, XS)) == 0.0
        assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))


class TestSparsityMechanism:
    def test_small_lr_updates_are_sparse_in_bf16(self, params):
        """The paper's core observation, reproduced mechanistically: at
        post-training learning rates, most Adam updates fall below the bf16
        ulp of their element, so the *stored policy* changes in ~1% of
        elements (Fig 3 / Table 4 territory)."""
        rng = np.random.default_rng(8)
        tokens = rand_tokens(rng, 8, 32, XS.vocab)
        mask = jnp.ones((8, 32), jnp.float32)
        adv = jnp.asarray(rng.standard_normal(8), jnp.float32)
        zeros = tuple(jnp.zeros_like(p) for p in params)
        new_p, _, _, _ = M.train_step(
            params, zeros, zeros, tokens, mask, adv, 1e-6, 1.0, XS
        )
        old_pol, new_pol = M.to_policy(params), M.to_policy(new_p)
        changed = total = 0
        for a, b in zip(old_pol, new_pol):
            ab = jax.lax.bitcast_convert_type(a, jnp.uint16)
            bb = jax.lax.bitcast_convert_type(b, jnp.uint16)
            changed += int((ab != bb).sum())
            total += ab.size
        rho = changed / total
        assert rho < 0.08, f"rho={rho:.4f} not sparse"
        assert changed > 0, "some elements must still change"

    def test_large_lr_updates_are_dense(self, params):
        """Pretraining-scale lr (1e-2) must produce dense updates —
        sparsity is an RL-regime property, not an artifact of our codec."""
        rng = np.random.default_rng(9)
        tokens = rand_tokens(rng, 8, 32, XS.vocab)
        mask = jnp.ones((8, 32), jnp.float32)
        adv = jnp.ones((8,), jnp.float32)
        zeros = tuple(jnp.zeros_like(p) for p in params)
        new_p, _, _, _ = M.train_step(
            params, zeros, zeros, tokens, mask, adv, 1e-2, 1.0, XS
        )
        old_pol, new_pol = M.to_policy(params), M.to_policy(new_p)
        changed = total = 0
        for a, b in zip(old_pol, new_pol):
            ab = jax.lax.bitcast_convert_type(a, jnp.uint16)
            bb = jax.lax.bitcast_convert_type(b, jnp.uint16)
            changed += int((ab != bb).sum())
            total += ab.size
        assert changed / total > 0.3, f"rho={changed / total:.4f}"


class TestDeltaDiffModel:
    def test_delta_diff_counts_policy_changes(self, params):
        pol = M.to_policy(params)
        # Flip a handful of stored values.
        bumped = list(pol)
        bumped[0] = bumped[0].at[0, 0].set(pol[0][0, 0] + 1.0)
        bumped[3] = bumped[3].at[0, 0, 0].set(pol[3][0, 0, 0] + 1.0)
        mask, nnz = M.delta_diff(pol, tuple(bumped))
        assert int(nnz) == 2
        assert mask.shape == (XS.param_count(),)
        assert int(mask.sum()) == 2

    def test_delta_diff_zero_for_identical(self, params):
        pol = M.to_policy(params)
        _mask, nnz = M.delta_diff(pol, pol)
        assert int(nnz) == 0
