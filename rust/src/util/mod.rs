//! Small self-contained utilities shared across the stack.
//!
//! The build environment is offline, so facilities usually pulled from
//! crates.io (rand, half, serde, criterion, proptest) are implemented here
//! in minimal, well-tested form.

pub mod bench;
pub mod bf16;
pub mod cli;
pub mod ema;
pub mod jsonl;
pub mod prop;
pub mod rng;

/// `util::json` is the JSON value/escape module (`jsonl` by its
/// historical name — it grew out of the `.jsonl` trace writer): the
/// builder/parser [`jsonl::Json`] plus the single shared string-escape
/// helper [`jsonl::escape_into`].
pub use self::jsonl as json;

pub use bf16::Bf16;
pub use ema::Ema;
pub use rng::Rng;

/// Lowercase hex rendering of a byte string (checksum display). The one
/// place checksum formatting lives — `StepLog::checksum_hex`, the CLI's
/// equivalence-witness line, and the short checkpoint-hash display all
/// route through here.
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Format a byte count with binary-ish human units (as the paper does: MB).
pub fn fmt_bytes(b: u64) -> String {
    const MB: f64 = 1e6;
    const GB: f64 = 1e9;
    let bf = b as f64;
    if bf >= GB {
        format!("{:.2} GB", bf / GB)
    } else if bf >= MB {
        format!("{:.1} MB", bf / MB)
    } else if bf >= 1e3 {
        format!("{:.1} KB", bf / 1e3)
    } else {
        format!("{} B", b)
    }
}

/// Format seconds compactly ("128 s", "4.71 s", "250 ms").
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{:.0} s", s)
    } else if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.0} ms", s * 1e3)
    } else {
        format!("{:.0} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_lowercase_two_digits_per_byte() {
        assert_eq!(hex(&[]), "");
        assert_eq!(hex(&[0x00, 0xab, 0xff, 0x07]), "00abff07");
        assert_eq!(hex(&[0u8; 32]).len(), 64);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2_500), "2.5 KB");
        assert_eq!(fmt_bytes(202_000_000), "202.0 MB");
        assert_eq!(fmt_bytes(15_600_000_000), "15.60 GB");
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(128.0), "128 s");
        assert_eq!(fmt_secs(4.71), "4.71 s");
        assert_eq!(fmt_secs(0.25), "250 ms");
        assert_eq!(fmt_secs(0.000_05), "50 us");
    }
}
