//! Minimal micro-benchmark harness (offline stand-in for criterion).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Each benchmark runs a warmup, then `reps` timed iterations, and reports
//! min / median / mean / p95 wall time plus derived throughput. Results
//! can be exported as machine-readable JSON (`write_json`) so the perf
//! trajectory is tracked across PRs (e.g. `BENCH_encoding.json`).

use crate::util::jsonl::Json;
use std::fmt;
use std::time::{Duration, Instant};

/// Typed failure of the JSON export path. JSON has no NaN/Inf — `Json`
/// would silently emit `null`, which the harness-side parser
/// (`bench::summary`) then rejects — so a non-finite derived metric or
/// throughput is refused up front with the offending field named.
#[derive(Debug)]
pub enum BenchWriteError {
    /// A value JSON cannot represent losslessly (NaN or ±Inf).
    NonFinite { case: String, field: String },
    Io(std::io::Error),
}

impl fmt::Display for BenchWriteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BenchWriteError::NonFinite { case, field } => write!(
                f,
                "bench {case:?}: {field:?} is NaN/Inf, which JSON cannot represent losslessly"
            ),
            BenchWriteError::Io(e) => write!(f, "writing bench json: {e}"),
        }
    }
}

impl std::error::Error for BenchWriteError {}

impl From<std::io::Error> for BenchWriteError {
    fn from(e: std::io::Error) -> BenchWriteError {
        BenchWriteError::Io(e)
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    /// Optional bytes processed per iteration, for GB/s reporting.
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.median.as_secs_f64() / 1e9)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} reps={:<4} min={:>10.3?} med={:>10.3?} mean={:>10.3?} p95={:>10.3?}",
            self.name, self.reps, self.min, self.median, self.mean, self.p95
        );
        if let Some(t) = self.throughput_gbps() {
            s.push_str(&format!("  {:>8.3} GB/s", t));
        }
        s
    }

    /// Machine-readable form (seconds; throughput in GB/s when known).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("reps", self.reps)
            .set("min_s", self.min.as_secs_f64())
            .set("median_s", self.median.as_secs_f64())
            .set("mean_s", self.mean.as_secs_f64())
            .set("p95_s", self.p95.as_secs_f64());
        if let Some(b) = self.bytes_per_iter {
            j = j.set("bytes_per_iter", b);
        }
        if let Some(t) = self.throughput_gbps() {
            j = j.set("gb_per_s", t);
        }
        j
    }
}

/// Benchmark runner with uniform defaults.
pub struct Bencher {
    pub warmup: usize,
    pub reps: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, reps: 15, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps, results: Vec::new() }
    }

    /// Time `f` (which should return something cheap to drop; use
    /// `std::hint::black_box` inside to defeat DCE).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_bytes_opt(name, None, &mut f)
    }

    /// Time `f` and report GB/s against `bytes` per iteration.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, mut f: F) -> &BenchResult {
        self.bench_bytes_opt(name, Some(bytes), &mut f)
    }

    fn bench_bytes_opt(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let r = BenchResult {
            name: name.to_string(),
            reps: self.reps,
            min: times[0],
            median: times[times.len() / 2],
            mean,
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
            bytes_per_iter: bytes,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Inject an externally measured result (a wall clock the caller
    /// timed itself, or a synthetic case in tests) into the recorded set.
    pub fn record(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    /// Build the JSON document for all recorded results, with optional
    /// derived metrics (e.g. speedup ratios) attached by the bench driver.
    pub fn to_json(&self, bench: &str, derived: &[(&str, f64)]) -> Json {
        let mut d = Json::obj();
        for (k, v) in derived {
            d = d.set(*k, *v);
        }
        Json::obj()
            .set("bench", bench)
            .set(
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            )
            .set("derived", d)
    }

    /// Write all recorded results as a JSON document (the cross-PR perf
    /// record, e.g. `BENCH_encoding.json`). Rejects non-finite values
    /// with a typed error *before* touching the file.
    pub fn write_json(
        &self,
        path: &std::path::Path,
        bench: &str,
        derived: &[(&str, f64)],
    ) -> Result<(), BenchWriteError> {
        for (k, v) in derived {
            if !v.is_finite() {
                return Err(BenchWriteError::NonFinite {
                    case: bench.to_string(),
                    field: k.to_string(),
                });
            }
        }
        for r in &self.results {
            if let Some(t) = r.throughput_gbps() {
                if !t.is_finite() {
                    return Err(BenchWriteError::NonFinite {
                        case: r.name.clone(),
                        field: "gb_per_s".to_string(),
                    });
                }
            }
        }
        let doc = self.to_json(bench, derived).to_string();
        std::fs::write(path, doc + "\n")?;
        println!("bench results written to {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_stats() {
        let mut b = Bencher::new(1, 5);
        let r = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.min <= r.median && r.median <= r.p95);
        assert_eq!(r.reps, 5);
    }

    #[test]
    fn json_export_contains_every_result_and_derived_metrics() {
        let mut b = Bencher::new(0, 3);
        b.bench("alpha", || {
            std::hint::black_box(1 + 1);
        });
        let buf = vec![0u8; 1024];
        b.bench_bytes("beta", buf.len() as u64, || {
            std::hint::black_box(buf.iter().map(|&x| x as u64).sum::<u64>());
        });
        let doc = b.to_json("encoding", &[("fused_speedup", 1.75)]).to_string();
        assert!(doc.contains("\"bench\":\"encoding\""));
        assert!(doc.contains("\"name\":\"alpha\""));
        assert!(doc.contains("\"name\":\"beta\""));
        assert!(doc.contains("\"gb_per_s\""));
        assert!(doc.contains("\"fused_speedup\":1.75"));
    }

    fn synthetic_result(name: &str, median: Duration, bytes: Option<u64>) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            reps: 1,
            min: median,
            median,
            mean: median,
            p95: median,
            bytes_per_iter: bytes,
        }
    }

    #[test]
    fn write_json_rejects_nan_and_inf_derived_metrics() {
        let mut b = Bencher::new(0, 1);
        b.bench("x", || {
            std::hint::black_box(1 + 1);
        });
        let path = std::env::temp_dir().join(format!("sprw-bench-nan-{}.json", std::process::id()));
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            match b.write_json(&path, "edge", &[("ok", 1.0), ("bad", bad)]) {
                Err(BenchWriteError::NonFinite { case, field }) => {
                    assert_eq!(case, "edge");
                    assert_eq!(field, "bad");
                }
                other => panic!("expected NonFinite for {bad}, got {other:?}"),
            }
        }
        assert!(!path.exists(), "rejected write must not leave a file behind");
    }

    #[test]
    fn write_json_rejects_infinite_throughput_from_zero_median() {
        let mut b = Bencher::new(0, 1);
        // A zero-duration median with bytes attached makes gb_per_s Inf —
        // the bug this typed error replaced (it used to serialize as a
        // silent JSON `null`).
        b.record(synthetic_result("instant", Duration::ZERO, Some(1024)));
        let path = std::env::temp_dir().join(format!("sprw-bench-inf-{}.json", std::process::id()));
        match b.write_json(&path, "edge", &[]) {
            Err(BenchWriteError::NonFinite { case, field }) => {
                assert_eq!(case, "instant");
                assert_eq!(field, "gb_per_s");
            }
            other => panic!("expected NonFinite, got {other:?}"),
        }
        assert!(!path.exists());
    }

    #[test]
    fn bench_names_escape_and_round_trip_through_the_parser() {
        let mut b = Bencher::new(0, 1);
        let name = "weird \"case\"\n\twith \\backslash and ctrl \u{1}";
        b.record(synthetic_result(name, Duration::from_micros(10), None));
        let doc = b.to_json("escape", &[("r\"atio\"", 0.5)]).to_string();
        let back = Json::parse(&doc).unwrap_or_else(|e| panic!("escaped doc must parse: {e}"));
        let results = back.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results[0].get("name").and_then(Json::as_str), Some(name));
        assert_eq!(back.get("derived").and_then(|d| d.get("r\"atio\"")).and_then(Json::as_f64), Some(0.5));
    }

    #[test]
    fn json_doc_nests_result_arrays_losslessly() {
        let mut b = Bencher::new(0, 1);
        b.record(synthetic_result("a", Duration::from_micros(5), Some(64)));
        b.record(synthetic_result("b", Duration::from_micros(7), None));
        let doc = b.to_json("nest", &[]).to_string();
        let back = Json::parse(&doc).unwrap();
        let results = back.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("bytes_per_iter").and_then(Json::as_u64), Some(64));
        assert!(results[1].get("bytes_per_iter").is_none());
        // Arrays nest arbitrarily through the same writer/parser pair.
        let nested = Json::obj().set(
            "grid",
            Json::Arr(vec![
                Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]),
                Json::Arr(vec![Json::Str("x".into())]),
            ]),
        );
        let round = Json::parse(&nested.to_string()).unwrap();
        assert_eq!(round, nested);
    }

    #[test]
    fn throughput_derives_from_bytes() {
        let mut b = Bencher::new(0, 3);
        let buf = vec![1u8; 1 << 16];
        let r = b.bench_bytes("sum-64k", buf.len() as u64, || {
            std::hint::black_box(buf.iter().map(|&x| x as u64).sum::<u64>());
        });
        assert!(r.throughput_gbps().unwrap() > 0.0);
    }
}
