//! Exponential moving average, the scheduler's throughput estimator
//! (Algorithm 1 line 16: tau_a <- beta*tau_a + (1-beta)*observed).

#[derive(Clone, Copy, Debug)]
pub struct Ema {
    beta: f64,
    value: Option<f64>,
}

impl Ema {
    /// `beta` is the weight on history; must be in [0, 1).
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        Ema { beta, value: None }
    }

    /// Seed with an initial estimate (e.g. a GPU-class prior).
    pub fn with_initial(beta: f64, init: f64) -> Self {
        let mut e = Ema::new(beta);
        e.value = Some(init);
        e
    }

    /// Blend in an observation; the first observation initializes.
    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.beta * v + (1.0 - self.beta) * x,
        });
    }

    /// Multiplicative decay (Algorithm 1 line 14: exclusion penalty).
    pub fn scale(&mut self, alpha: f64) {
        if let Some(v) = self.value.as_mut() {
            *v *= alpha;
        }
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_initializes() {
        let mut e = Ema::new(0.8);
        assert!(e.get().is_none());
        e.observe(100.0);
        assert_eq!(e.get(), Some(100.0));
    }

    #[test]
    fn converges_to_constant_signal() {
        let mut e = Ema::with_initial(0.5, 0.0);
        for _ in 0..50 {
            e.observe(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn blending_weights() {
        let mut e = Ema::with_initial(0.75, 100.0);
        e.observe(0.0);
        assert!((e.get().unwrap() - 75.0).abs() < 1e-12);
    }

    #[test]
    fn scale_decays() {
        let mut e = Ema::with_initial(0.9, 200.0);
        e.scale(0.5);
        assert_eq!(e.get(), Some(100.0));
    }
}
