//! Minimal JSON value builder + emitter (offline stand-in for serde_json).
//!
//! Used for metrics/timeline export (`*.jsonl` traces) and experiment
//! results. Only serialization is needed; parsing is deliberately omitted.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics when self is not an object).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_serialization_sorted_keys() {
        let j = Json::obj().set("b", 2u64).set("a", "x").set("c", true);
        assert_eq!(j.to_string(), r#"{"a":"x","b":2,"c":true}"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn numbers_integral_and_float() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn arrays_nest() {
        let j: Json = vec![1u64, 2, 3].into();
        assert_eq!(j.to_string(), "[1,2,3]");
    }
}
