//! Minimal JSON value builder + emitter + parser (offline stand-in for
//! serde_json).
//!
//! Used for metrics/timeline export (`*.jsonl` traces), experiment
//! results, and the durable run journal / version manifests
//! (`delta::store`), whose recovery path needs [`Json::parse`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Escape `s` into `out` as the *interior* of a JSON string literal (no
/// surrounding quotes): `"` and `\` are backslash-escaped, control
/// characters become `\n`/`\t`/`\r` or `\u00XX`. This is the one place
/// JSON string escaping lives — [`Json`]'s emitter, the bench exporter,
/// and the daemon's hand-framed SSE `data:` lines all route through it.
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// Convenience form of [`escape_into`]: a fresh escaped string.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(&mut out, s);
    out
}

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics when self is not an object).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                escape_into(out, s);
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document. Rejects trailing non-whitespace content
    /// (a truncated or torn journal line never parses as valid). Errors
    /// carry the byte offset of the failure.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { s: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            return Err(format!("trailing content at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (None when self is not an object or the key
    /// is absent).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integral read-back. JSON numbers are f64 here, so this only covers
    /// values up to 2^53 exactly — full-range u64s (seeds, fingerprints,
    /// hashes) must travel as hex strings instead.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x < 9.007_199_254_740_992e15 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.s.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected byte 0x{b:02x} at {}", self.pos)),
            None => Err(format!("unexpected end of input at byte {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.pos])
            .map_err(|_| format!("invalid number at byte {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(format!("unterminated string at byte {}", self.pos)),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Stay at the last hex digit; the shared
                            // `pos += 1` below steps past it.
                            self.pos -= 1;
                            let c = char::from_u32(cp)
                                .ok_or_else(|| format!("bad \\u escape at byte {}", self.pos))?;
                            out.push(c);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Multi-byte UTF-8 sequences pass through verbatim.
                    let rest = std::str::from_utf8(&self.s[self.pos..])
                        .map_err(|_| format!("invalid utf-8 at byte {}", self.pos))?;
                    let c = rest.chars().next().expect("non-empty checked above");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let b = self
                .peek()
                .ok_or_else(|| format!("truncated \\u escape at byte {}", self.pos))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| format!("bad hex digit at byte {}", self.pos))?;
            cp = cp * 16 + d;
            self.pos += 1;
        }
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_serialization_sorted_keys() {
        let j = Json::obj().set("b", 2u64).set("a", "x").set("c", true);
        assert_eq!(j.to_string(), r#"{"a":"x","b":2,"c":true}"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn escape_helper_covers_quotes_backslashes_and_controls() {
        // The factored helper is what Json::Str emission and the daemon's
        // response bodies share; pin its exact output.
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("q\"b\\"), "q\\\"b\\\\");
        assert_eq!(escape("a\nb\tc\r"), "a\\nb\\tc\\r");
        assert_eq!(escape("\u{1}\u{1f}"), "\\u0001\\u001f");
        assert_eq!(escape("snow\u{2603}man"), "snow\u{2603}man");
        // Round trip through the parser: a hand-framed string built from
        // escape() parses back to the original.
        for s in ["", "x", "a\"b\\c\nd\u{2}", "ctrl\u{0}end"] {
            let doc = format!("\"{}\"", escape(s));
            assert_eq!(Json::parse(&doc).unwrap().as_str(), Some(s), "{s:?}");
        }
    }

    #[test]
    fn numbers_integral_and_float() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn arrays_nest() {
        let j: Json = vec![1u64, 2, 3].into();
        assert_eq!(j.to_string(), "[1,2,3]");
    }

    #[test]
    fn parse_round_trips_emitter_output() {
        let j = Json::obj()
            .set("version", 7u64)
            .set("witness", "ab\"c\\d\ne")
            .set("seeds", vec![1u64, 2, 3])
            .set("pi", 3.5)
            .set("neg", -4i64)
            .set("flag", true)
            .set("none", Json::Null);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn parse_handles_whitespace_and_nesting() {
        let j = Json::parse(" { \"a\" : [ 1 , { \"b\" : null } ] } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[0].as_u64(), Some(1));
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse("\"a\\u0041\\n\"").unwrap();
        assert_eq!(j.as_str(), Some("aA\n"));
    }

    #[test]
    fn parse_rejects_torn_input() {
        assert!(Json::parse(r#"{"version":7,"wit"#).is_err());
        assert!(Json::parse(r#"{"a":1} trailing"#).is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn as_u64_guards_precision() {
        assert_eq!(Json::Num(9.007_199_254_740_991e15).as_u64(), Some((1u64 << 53) - 1));
        assert_eq!(Json::Num(9.007_199_254_740_992e15).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
