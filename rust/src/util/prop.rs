//! Tiny property-testing helper (offline stand-in for proptest).
//!
//! Runs a closure over `cases` RNG-derived inputs; on failure it reports the
//! case index and seed so the exact input can be replayed:
//!
//! ```no_run
//! use sparrowrl::util::prop;
//! prop::check("reverse twice is identity", 100, |rng| {
//!     let n = rng.range(0, 50);
//!     let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Rng;

/// Base seed; override with SPARROW_PROP_SEED to replay CI failures.
fn base_seed() -> u64 {
    std::env::var("SPARROW_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `f` for `cases` independently-seeded inputs. Panics (propagating the
/// inner assertion) with replay info on the first failing case.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: SPARROW_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Generate a sorted vector of `k` distinct u64 indices below `n` —
/// the canonical "sparse update positions" generator.
pub fn sparse_indices(rng: &mut Rng, n: u64, k: usize) -> Vec<u64> {
    assert!((k as u64) <= n);
    if n == 0 || k == 0 {
        return Vec::new();
    }
    // For small density sample-and-dedup; for dense fall back to shuffle.
    if (k as u64) * 4 < n {
        let mut set = std::collections::BTreeSet::new();
        while set.len() < k {
            set.insert(rng.below(n));
        }
        set.into_iter().collect()
    } else {
        let mut all: Vec<u64> = (0..n).collect();
        rng.shuffle(&mut all);
        all.truncate(k);
        all.sort_unstable();
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("addition commutes", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("always fails", 3, |_rng| {
            assert!(false);
        });
    }

    #[test]
    fn sparse_indices_sorted_distinct_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let n = rng.range(1, 10_000) as u64;
            let k = rng.range(0, (n as usize).min(200) + 1);
            let idx = sparse_indices(&mut rng, n, k);
            assert_eq!(idx.len(), k);
            for w in idx.windows(2) {
                assert!(w[0] < w[1]);
            }
            if let Some(&last) = idx.last() {
                assert!(last < n);
            }
        }
    }
}
