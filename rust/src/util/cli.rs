//! Minimal CLI argument parser (offline stand-in for clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.opts.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                panic!("--{name}: cannot parse {v:?}");
            }),
            None => default,
        }
    }

    /// Comma-separated list option, e.g. `--sizes 4,8,14`.
    pub fn list_or<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: cannot parse element {s:?}"))
                })
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["--model", "sparrow-m", "--steps=7", "run"]);
        assert_eq!(a.get("model"), Some("sparrow-m"));
        assert_eq!(a.parse_or("steps", 0u32), 7);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--verbose", "--seed", "9"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or("seed", 0u64), 9);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--bw", "0.25,1,10"]);
        assert_eq!(a.list_or::<f64>("bw", &[]), vec![0.25, 1.0, 10.0]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.parse_or("streams", 4usize), 4);
        assert_eq!(a.str_or("model", "sparrow-s"), "sparrow-s");
        assert!(!a.flag("verbose"));
    }
}
