//! Deterministic PRNG (SplitMix64 seeding + xoshiro256**).
//!
//! Every stochastic component in the simulator and the test suites draws
//! from this generator so that runs are exactly reproducible from a seed.

/// xoshiro256** seeded via SplitMix64. Not cryptographic; fast and
/// statistically solid for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (e.g. per actor / per link).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-15 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Pareto(shape a, scale x_m) — heavy tails for RL gradient magnitudes
    /// and rollout-length long-tails.
    pub fn pareto(&mut self, shape: f64, scale: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-15 {
                break u;
            }
        };
        scale / u.powf(1.0 / shape)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn pareto_exceeds_scale() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.pareto(1.5, 2.0) >= 2.0);
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        let idx = r.sample_indices(100, 17);
        assert_eq!(idx.len(), 17);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
