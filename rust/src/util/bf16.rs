//! Minimal bfloat16 implementation (offline stand-in for the `half` crate).
//!
//! bf16 is the storage dtype for policy weights and delta values. The whole
//! lossless-delta argument of the paper rests on bit-exact bf16 handling,
//! so conversions here are defined purely on bit patterns:
//!   f32 -> bf16 uses round-to-nearest-even on the dropped 16 bits (what
//!   XLA/JAX do); bf16 -> f32 is exact (append 16 zero bits).

/// A bfloat16 value stored as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Round-to-nearest-even conversion from f32 (matches XLA semantics,
    /// including NaN preservation).
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet the NaN, keep the sign; never round a NaN to Inf.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // round-half-to-even on bit 16
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    /// Exact widening conversion.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn from_bits(b: u16) -> Bf16 {
        Bf16(b)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F80) == 0x7F80 && (self.0 & 0x007F) != 0
    }
}

impl std::fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}bf16", self.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// Quantize an f32 slice to bf16 bit patterns in place (returns a new vec).
pub fn quantize_slice(xs: &[f32]) -> Vec<Bf16> {
    xs.iter().map(|&x| Bf16::from_f32(x)).collect()
}

/// Widen a bf16 slice to f32.
pub fn widen_slice(xs: &[Bf16]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_round_trip() {
        let tiny = (2.0f32).powi(-125); // bf16-exact small normal
        for &x in &[0.0f32, 1.0, -1.0, 0.5, -0.5, 2.0, 256.0, tiny] {
            let b = Bf16::from_f32(x);
            assert_eq!(b.to_f32(), x, "{x} should be bf16-exact");
        }
    }

    #[test]
    fn widening_is_exact_for_all_finite_patterns() {
        // Every bf16 bit pattern must survive bf16 -> f32 -> bf16 untouched.
        for bits in 0..=u16::MAX {
            let b = Bf16::from_bits(bits);
            if b.is_nan() {
                continue;
            }
            let round = Bf16::from_f32(b.to_f32());
            assert_eq!(round.to_bits(), bits, "pattern {bits:#06x}");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between bf16(1.0) and the next
        // representable value; ties go to even (here: stays at 1.0).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).to_bits(), 0x3F80);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).to_bits(), 0x3F81);
        // Odd lsb ties round up to even.
        let halfway_odd = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(halfway_odd).to_bits(), 0x3F82);
    }

    #[test]
    fn nan_stays_nan_inf_stays_inf() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn small_update_vanishes_under_bf16() {
        // The mechanism behind the paper's sparsity: sub-ulp updates do not
        // change the stored bf16 value.
        let w = 0.02f32;
        let b0 = Bf16::from_f32(w);
        let b1 = Bf16::from_f32(b0.to_f32() + 1e-8);
        assert_eq!(b0, b1);
        // ...while a large-enough update does.
        let b2 = Bf16::from_f32(b0.to_f32() + 1e-3);
        assert_ne!(b0, b2);
    }
}
