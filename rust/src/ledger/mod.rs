//! Job Ledger: prompt pool, time-bounded leases, and the result-acceptance
//! predicate (paper §4, §5.4).
//!
//! Coordination is deliberately *implicit*: an actor claims prompts under a
//! lease sized at 2-3x the median completion time; if it fails, is
//! preempted, or is partitioned away, the lease expires and the prompts
//! return to the pool for surviving actors — no global barrier, no failure
//! detector. The Trainer accepts a result only if
//!
//!   (1) the lease is still valid        (t_r <= t_expire)
//!   (2) the behaviour version matches   (v_r == v_job)
//!   (3) the checkpoint hash matches     (h_r == h(v_job))
//!
//! which also prevents stale rollouts from poisoning training.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::time::Instant;

pub type PromptId = u64;
pub type ActorId = u32;

/// Lease time source. The ledger itself is clock-agnostic
/// (`issue`/`submit`/`expire` take `now`); callers pick the variant:
///
/// * [`Clock::Wall`] — monotone seconds since construction. The real
///   runtimes (`rt/local`, `rt/pipeline`) anchor one at run start so
///   in-flight work — rollouts generating concurrently with training —
///   is leased against actual elapsed seconds and genuinely expires on
///   stalls, crashes, and partitions.
/// * [`Clock::Manual`] — deterministic virtual time advanced explicitly
///   with [`Clock::advance`]. Lease-expiry tests drive failure scenarios
///   without sleeping, and the deterministic executors use µs-scale ticks
///   so leases (floored at seconds) never expire spuriously.
#[derive(Clone, Copy, Debug)]
pub enum Clock {
    /// Monotone wall time, seconds since the clock was created.
    Wall(Instant),
    /// Virtual time; advances only via [`Clock::advance`].
    Manual(f64),
}

impl Clock {
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    pub fn manual(start_s: f64) -> Clock {
        Clock::Manual(start_s)
    }

    /// Current time in seconds (monotone, never negative).
    pub fn now(&self) -> f64 {
        match self {
            Clock::Wall(origin) => origin.elapsed().as_secs_f64(),
            Clock::Manual(t) => *t,
        }
    }

    /// Advance a manual clock by `dt` seconds; no-op on a wall clock
    /// (wall time advances itself).
    pub fn advance(&mut self, dt: f64) {
        if let Clock::Manual(t) = self {
            *t += dt;
        }
    }

    pub fn is_manual(&self) -> bool {
        matches!(self, Clock::Manual(_))
    }
}

/// Lease policy: duration = clamp(multiplier * median completion).
#[derive(Clone, Copy, Debug)]
pub struct LeasePolicy {
    pub multiplier: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// How often the hub sweeps for expired leases (and polls its
    /// endpoint when idle), in milliseconds. Soak tests and slow WAN
    /// presets tune this instead of inheriting a hardcoded 25 ms; zero
    /// is rejected at spec validation (`SpecError::ZeroSweepInterval`).
    pub sweep_ms: u64,
}

impl Default for LeasePolicy {
    fn default() -> Self {
        // Paper: "time-bounded lease (2-3x median completion time)".
        LeasePolicy { multiplier: 2.5, min_s: 10.0, max_s: 1800.0, sweep_ms: 25 }
    }
}

/// An outstanding claim on one prompt.
#[derive(Clone, Debug)]
pub struct Lease {
    pub prompt: PromptId,
    pub actor: ActorId,
    pub issued_at: f64,
    pub expires_at: f64,
    /// Policy version the rollout must be generated on.
    pub version: u64,
    /// Integrity hash of that version's checkpoint.
    pub hash: [u8; 32],
}

/// Why a submission was rejected (§5.4's predicate, itemized).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reject {
    UnknownLease,
    WrongActor,
    LeaseExpired,
    VersionMismatch,
    HashMismatch,
}

/// Ledger statistics (exported to metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct LedgerStats {
    pub issued: u64,
    pub completed: u64,
    pub expired: u64,
    pub rejected: u64,
}

/// The Trainer Hub's job ledger.
pub struct JobLedger {
    policy: LeasePolicy,
    pending: VecDeque<PromptId>,
    leases: HashMap<PromptId, Lease>,
    /// Completion-time samples for the median estimate (bounded window).
    samples: VecDeque<f64>,
    stats: LedgerStats,
    /// Expiry index: expiry time -> prompts (approximate, lazily cleaned).
    expiry: BTreeMap<u64, Vec<PromptId>>,
}

impl JobLedger {
    pub fn new(policy: LeasePolicy) -> JobLedger {
        JobLedger {
            policy,
            pending: VecDeque::new(),
            leases: HashMap::new(),
            samples: VecDeque::new(),
            stats: LedgerStats::default(),
            expiry: BTreeMap::new(),
        }
    }

    /// Add prompts to the pool.
    pub fn post(&mut self, prompts: impl IntoIterator<Item = PromptId>) {
        self.pending.extend(prompts);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn outstanding(&self) -> usize {
        self.leases.len()
    }

    pub fn stats(&self) -> LedgerStats {
        self.stats
    }

    /// Current lease duration from the completion-time estimate.
    pub fn lease_duration(&self) -> f64 {
        let median = self.median_completion().unwrap_or(self.policy.min_s);
        (self.policy.multiplier * median).clamp(self.policy.min_s, self.policy.max_s)
    }

    fn median_completion(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = self.samples.iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(v[v.len() / 2])
    }

    /// Claim up to `n` prompts for `actor` running `version`/`hash`.
    pub fn issue(
        &mut self,
        actor: ActorId,
        version: u64,
        hash: [u8; 32],
        now: f64,
        n: usize,
    ) -> Vec<PromptId> {
        let dur = self.lease_duration();
        let mut out = Vec::with_capacity(n.min(self.pending.len()));
        for _ in 0..n {
            let Some(p) = self.pending.pop_front() else { break };
            let lease = Lease {
                prompt: p,
                actor,
                issued_at: now,
                expires_at: now + dur,
                version,
                hash,
            };
            self.expiry
                .entry((lease.expires_at * 1000.0) as u64)
                .or_default()
                .push(p);
            self.leases.insert(p, lease);
            self.stats.issued += 1;
            out.push(p);
        }
        out
    }

    /// Re-lease specific pooled prompts to `actor`, preserving the
    /// caller's order (the failover path: a dead actor's prompts return
    /// to the pool via [`expire`](Self::expire) /
    /// [`revoke_actor`](Self::revoke_actor), then the hub re-issues the
    /// *original job's* prompt sequence to one survivor so regeneration
    /// is bit-reproducible). Prompts not currently pending are skipped;
    /// returns the prompts actually re-leased, in request order.
    pub fn reissue(
        &mut self,
        prompts: &[PromptId],
        actor: ActorId,
        version: u64,
        hash: [u8; 32],
        now: f64,
    ) -> Vec<PromptId> {
        let dur = self.lease_duration();
        let mut out = Vec::with_capacity(prompts.len());
        for &p in prompts {
            let Some(pos) = self.pending.iter().position(|&q| q == p) else { continue };
            self.pending.remove(pos);
            let lease = Lease {
                prompt: p,
                actor,
                issued_at: now,
                expires_at: now + dur,
                version,
                hash,
            };
            self.expiry
                .entry((lease.expires_at * 1000.0) as u64)
                .or_default()
                .push(p);
            self.leases.insert(p, lease);
            self.stats.issued += 1;
            out.push(p);
        }
        out
    }

    /// Submit a result: the acceptance predicate, verbatim.
    pub fn submit(
        &mut self,
        actor: ActorId,
        prompt: PromptId,
        result_version: u64,
        result_hash: [u8; 32],
        now: f64,
    ) -> Result<(), Reject> {
        let lease = self.leases.get(&prompt).ok_or(Reject::UnknownLease)?;
        if lease.actor != actor {
            self.stats.rejected += 1;
            return Err(Reject::WrongActor);
        }
        if now > lease.expires_at {
            self.stats.rejected += 1;
            return Err(Reject::LeaseExpired);
        }
        if lease.version != result_version {
            self.stats.rejected += 1;
            return Err(Reject::VersionMismatch);
        }
        if lease.hash != result_hash {
            self.stats.rejected += 1;
            return Err(Reject::HashMismatch);
        }
        let lease = self.leases.remove(&prompt).unwrap();
        self.stats.completed += 1;
        self.samples.push_back(now - lease.issued_at);
        if self.samples.len() > 256 {
            self.samples.pop_front();
        }
        Ok(())
    }

    /// Expire overdue leases, returning their prompts to the pool
    /// (actor crash, preemption, and link partition all land here).
    pub fn expire(&mut self, now: f64) -> Vec<PromptId> {
        let cutoff = (now * 1000.0) as u64;
        let keys: Vec<u64> = self.expiry.range(..=cutoff).map(|(&k, _)| k).collect();
        let mut returned = Vec::new();
        for k in keys {
            for p in self.expiry.remove(&k).unwrap() {
                // A lease may have completed already (lazily indexed).
                if let Some(lease) = self.leases.get(&p) {
                    if now > lease.expires_at {
                        self.leases.remove(&p);
                        self.pending.push_back(p);
                        self.stats.expired += 1;
                        returned.push(p);
                    }
                }
            }
        }
        returned
    }

    /// Forcibly revoke every lease held by `actor` (explicit failure
    /// signal, e.g. connection reset in the real runtime). Lease expiry
    /// would catch this anyway; revocation just shortens the window.
    pub fn revoke_actor(&mut self, actor: ActorId) -> Vec<PromptId> {
        let prompts: Vec<PromptId> = self
            .leases
            .values()
            .filter(|l| l.actor == actor)
            .map(|l| l.prompt)
            .collect();
        for p in &prompts {
            self.leases.remove(p);
            self.pending.push_back(*p);
            self.stats.expired += 1;
        }
        prompts
    }

    /// Hand an actor's outstanding leases back to the pool *without* the
    /// expiry penalty: a graceful drain (scripted leave, spot-preemption
    /// warning, clean `Bye`) is not a failure, so it must not inflate
    /// `LedgerStats::expired` or feed the completion-time estimator.
    /// Prompts return to the pending queue in prompt order (the original
    /// posting order), so the reissue that follows is deterministic.
    pub fn revoke_actor_without_penalty(&mut self, actor: ActorId) -> Vec<PromptId> {
        let mut prompts: Vec<PromptId> = self
            .leases
            .values()
            .filter(|l| l.actor == actor)
            .map(|l| l.prompt)
            .collect();
        prompts.sort_unstable();
        for p in &prompts {
            self.leases.remove(p);
            self.pending.push_back(*p);
        }
        prompts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: [u8; 32] = [7u8; 32];

    fn ledger() -> JobLedger {
        let mut l = JobLedger::new(LeasePolicy { multiplier: 2.0, min_s: 10.0, max_s: 100.0, ..Default::default() });
        l.post(0..10);
        l
    }

    #[test]
    fn wall_clock_is_monotone_and_drives_lease_expiry() {
        let c = Clock::wall();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.now();
        assert!(a >= 0.0 && b > a, "monotone: {a} -> {b}");
        assert!(!c.is_manual());
        // A lease issued at wall time `a` is still valid "now" (real leases
        // are >= min_s seconds long, far beyond this test's runtime).
        let mut l = ledger();
        let p = l.issue(1, 5, H, a, 1)[0];
        assert!(l.submit(1, p, 5, H, c.now()).is_ok());
    }

    #[test]
    fn manual_clock_drives_expiry_without_sleeping() {
        // The deterministic failure-test pattern: a Manual clock advanced
        // past the lease horizon expires leases with zero wall time spent.
        let mut c = Clock::manual(0.0);
        let mut l = ledger();
        let got = l.issue(1, 5, H, c.now(), 3);
        assert_eq!(got.len(), 3);
        c.advance(19.0); // duration = multiplier * min_s = 20 s
        assert!(l.expire(c.now()).is_empty(), "not yet due");
        c.advance(2.0);
        let returned = l.expire(c.now());
        assert_eq!(returned.len(), 3);
        assert_eq!(l.stats().expired, 3);
        // Wall clocks ignore advance (their time is real).
        let mut w = Clock::wall();
        let t0 = w.now();
        w.advance(1e9);
        assert!(w.now() - t0 < 1.0, "advance must not warp a wall clock");
    }

    #[test]
    fn reissue_preserves_request_order_and_skips_unpooled() {
        let mut l = ledger();
        let got = l.issue(1, 5, H, 0.0, 4); // prompts 0..4 leased to actor 1
        assert_eq!(got, vec![0, 1, 2, 3]);
        // Actor 1 dies; its prompts return to the pool (arbitrary order).
        let mut revoked = l.revoke_actor(1);
        revoked.sort_unstable();
        assert_eq!(revoked, vec![0, 1, 2, 3]);
        // Failover re-leases the ORIGINAL job order to actor 2; prompt 77
        // was never posted, so it is simply skipped.
        let again = l.reissue(&[2, 0, 3, 1, 77], 2, 5, H, 1.0);
        assert_eq!(again, vec![2, 0, 3, 1], "caller order, not pool order");
        assert_eq!(l.outstanding(), 4);
        for p in [2u64, 0, 3, 1] {
            assert!(l.submit(2, p, 5, H, 2.0).is_ok());
        }
        // A prompt already leased elsewhere cannot be re-leased.
        let held = l.issue(3, 5, H, 3.0, 1);
        assert_eq!(l.reissue(&held, 2, 5, H, 3.0), Vec::<u64>::new());
    }

    #[test]
    fn issue_claims_from_pool() {
        let mut l = ledger();
        let got = l.issue(1, 5, H, 0.0, 4);
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(l.pending_len(), 6);
        assert_eq!(l.outstanding(), 4);
    }

    #[test]
    fn valid_submission_accepted() {
        let mut l = ledger();
        let p = l.issue(1, 5, H, 0.0, 1)[0];
        assert!(l.submit(1, p, 5, H, 3.0).is_ok());
        assert_eq!(l.stats().completed, 1);
        assert_eq!(l.outstanding(), 0);
    }

    #[test]
    fn predicate_rejects_each_violation() {
        let mut l = ledger();
        let p = l.issue(1, 5, H, 0.0, 1)[0];
        assert_eq!(l.submit(2, p, 5, H, 1.0), Err(Reject::WrongActor));
        assert_eq!(l.submit(1, p, 4, H, 1.0), Err(Reject::VersionMismatch));
        assert_eq!(l.submit(1, p, 5, [0u8; 32], 1.0), Err(Reject::HashMismatch));
        assert_eq!(l.submit(1, p, 5, H, 999.0), Err(Reject::LeaseExpired));
        assert_eq!(l.submit(1, 42, 5, H, 1.0), Err(Reject::UnknownLease));
        // Still claimable by expiry.
        assert_eq!(l.outstanding(), 1);
        assert_eq!(l.stats().rejected, 4);
    }

    #[test]
    fn expiry_returns_prompts_to_pool() {
        let mut l = ledger();
        let got = l.issue(1, 5, H, 0.0, 3);
        assert_eq!(l.pending_len(), 7);
        // No samples yet: duration = multiplier * min_s = 20 s.
        assert!(l.expire(19.0).is_empty(), "not yet due");
        let returned = l.expire(21.0);
        assert_eq!(returned.len(), 3);
        assert_eq!(l.pending_len(), 10);
        assert_eq!(l.outstanding(), 0);
        // Expired prompts return to the back of the pool and are
        // re-issuable to another actor.
        let again = l.issue(2, 5, H, 22.0, 10);
        assert_eq!(again.len(), 10);
        assert!(got.iter().all(|p| again.contains(p)));
    }

    #[test]
    fn completed_lease_not_expired_later() {
        let mut l = ledger();
        let p = l.issue(1, 5, H, 0.0, 1)[0];
        l.submit(1, p, 5, H, 2.0).unwrap();
        let returned = l.expire(50.0);
        assert!(returned.is_empty());
        assert_eq!(l.stats().expired, 0);
    }

    #[test]
    fn lease_duration_tracks_median_completion() {
        let mut l = ledger();
        let base = l.lease_duration();
        assert_eq!(base, 20.0); // multiplier * min_s with no samples
        // Feed 8 s completions (inside the 20 s lease) -> duration 16 s.
        let mut now = 0.0;
        for i in 0..20 {
            let p = l.issue(1, 5, H, now, 1);
            if p.is_empty() {
                l.post([100 + i]);
                continue;
            }
            now += 8.0;
            l.submit(1, p[0], 5, H, now).unwrap();
        }
        assert!((l.lease_duration() - 16.0).abs() < 1e-6);
    }

    #[test]
    fn revoke_actor_reclaims_everything() {
        let mut l = ledger();
        l.issue(1, 5, H, 0.0, 4);
        l.issue(2, 5, H, 0.0, 2);
        let reclaimed = l.revoke_actor(1);
        assert_eq!(reclaimed.len(), 4);
        assert_eq!(l.outstanding(), 2);
        assert_eq!(l.pending_len(), 8);
    }

    #[test]
    fn no_double_assignment_of_live_lease() {
        let mut l = ledger();
        let a = l.issue(1, 5, H, 0.0, 10);
        assert_eq!(a.len(), 10);
        // Pool drained; nothing to issue while leases live.
        assert!(l.issue(2, 5, H, 1.0, 5).is_empty());
    }

    #[test]
    fn prop_ledger_conserves_prompts() {
        crate::util::prop::check("ledger conservation", 25, |rng| {
            let mut l = JobLedger::new(LeasePolicy { multiplier: 2.0, min_s: 5.0, max_s: 50.0, ..Default::default() });
            let total = rng.range(1, 50) as u64;
            l.post(0..total);
            let mut now = 0.0;
            let mut completed = 0u64;
            for _ in 0..200 {
                now += rng.f64() * 3.0;
                match rng.range(0, 3) {
                    0 => {
                        let actor = rng.range(1, 4) as ActorId;
                        l.issue(actor, 1, H, now, rng.range(1, 5));
                    }
                    1 => {
                        // Submit a random outstanding lease as its owner.
                        let leases: Vec<(PromptId, ActorId, f64)> = l
                            .leases
                            .iter()
                            .map(|(&p, le)| (p, le.actor, le.expires_at))
                            .collect();
                        if let Some(&(p, a, exp)) = leases.first() {
                            if now <= exp {
                                l.submit(a, p, 1, H, now).unwrap();
                                completed += 1;
                            }
                        }
                    }
                    _ => {
                        l.expire(now);
                    }
                }
                // Invariant: every prompt is pending, leased, or completed.
                assert_eq!(
                    l.pending_len() as u64 + l.outstanding() as u64 + completed,
                    total
                );
            }
        });
    }
}
