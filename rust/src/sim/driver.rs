//! The step-structured simulation driver.
//!
//! Pipeline semantics follow the paper's one-step asynchronous RL (§2.1,
//! Fig 7): the batch for step i is generated on the *stale* policy
//! `pi_{i-1}` while the Trainer computes `pi_i` from batch i-1 and streams
//! `delta_i` outward; actors activate `pi_i` at the end of their running
//! batch. Batch i+1 therefore starts at
//! `max(batch_i end, delta_i delivered) + commit delay`,
//! so synchronization is hidden iff the train+transfer pipeline fits one
//! generation window — exactly the deadline §5.2 describes. Entities and
//! durations come from the calibrated `ComputeModel` and netsim links;
//! batch splitting uses the real Algorithm-1 `Scheduler`.

use super::compute::{delta_payload_bytes, ComputeModel};
use super::{RegionSpec, System};
use crate::config::{GpuClass, ModelSpec};
use crate::data::Benchmark;
use crate::metrics::{SpanKind, Timeline};
use crate::netsim::Link;
use crate::scheduler::{Scheduler, SchedulerConfig, VersionState};
use crate::transport::plan::{intra_region_link, TransferPlan};
use crate::util::Rng;

/// An injected actor failure: the actor produces nothing at `step`; its
/// prompts return via lease expiry and survivors redo them (§5.4).
#[derive(Clone, Copy, Debug)]
pub struct FailureEvent {
    pub actor: usize,
    pub step: u64,
}

/// Simulation configuration for one run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub model: ModelSpec,
    pub bench: Benchmark,
    pub system: System,
    pub regions: Vec<RegionSpec>,
    pub trainer_gpus: usize,
    /// Total rollouts per step, split across actors by the scheduler.
    pub batch: u64,
    pub steps: u64,
    /// Parallel TCP streams for multi-stream plans.
    pub streams: usize,
    /// Heterogeneity-aware (Algorithm 1) vs uniform splitting (Table 7).
    pub hetero_sched: bool,
    /// Bandwidth-aware gate: feed each region's observed delta-delivery
    /// throughput back into allocation, shrinking the share of regions
    /// whose predicted delivery exceeds the generation window (§5.2's
    /// "throughput- and bandwidth-aware scheduling"; used by `exp wan`).
    pub bandwidth_gate: bool,
    /// Per-transfer link jitter sampling.
    pub jittered: bool,
    pub seed: u64,
    pub failures: Vec<FailureEvent>,
}

impl SimConfig {
    /// Fleet generation-window target used to size the default batch
    /// (Table 2's ~45 s rollout window, less result-return headroom).
    pub const TARGET_WINDOW_S: f64 = 40.0;

    /// Capacity-matched defaults mirroring the §7.1 testbed: the batch is
    /// sized so the fleet's generation window is ~75 s (G=512-scale groups
    /// on the paper's 4/8/12-actor fleets), trainer GPUs scale 2/4/6-ish
    /// with model size.
    pub fn paper_testbed(
        model: ModelSpec,
        bench: Benchmark,
        system: System,
        regions: Vec<RegionSpec>,
    ) -> SimConfig {
        let trainer_gpus = (model.total_params() as f64 / 2.05e9).round().clamp(2.0, 8.0) as usize;
        let cm = ComputeModel::new(bench, trainer_gpus);
        let fleet_rate: f64 = regions
            .iter()
            .flat_map(|r| r.gpus.iter())
            .map(|&g| cm.rollout_rate(g, &model))
            .sum();
        let batch = ((Self::TARGET_WINDOW_S * fleet_rate) / cm.gen_tokens_per_sample).round() as u64;
        SimConfig {
            model,
            bench,
            system,
            regions,
            trainer_gpus,
            batch: batch.max(1),
            steps: 7,
            streams: 4,
            hetero_sched: true,
            bandwidth_gate: false,
            jittered: false,
            seed: 0,
            failures: Vec::new(),
        }
    }
}

/// Per-step outcome.
#[derive(Clone, Copy, Debug)]
pub struct StepStat {
    pub step: u64,
    pub step_time: f64,
    pub transfer_time: f64,
    pub payload_bytes: u64,
    pub rollout_window: f64,
    pub train_time: f64,
}

/// Aggregate result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub system: System,
    pub steps: Vec<StepStat>,
    pub total_time: f64,
    pub total_gen_tokens: u64,
    pub timeline: Timeline,
}

impl SimResult {
    /// The paper's primary metric: tokens/s across the entire system.
    pub fn throughput(&self) -> f64 {
        self.total_gen_tokens as f64 / self.total_time.max(1e-9)
    }

    pub fn avg_step_time(&self) -> f64 {
        self.steps.iter().map(|s| s.step_time).sum::<f64>() / self.steps.len().max(1) as f64
    }

    pub fn avg_transfer_time(&self) -> f64 {
        self.steps.iter().map(|s| s.transfer_time).sum::<f64>() / self.steps.len().max(1) as f64
    }

    pub fn payload_bytes(&self) -> u64 {
        self.steps.first().map(|s| s.payload_bytes).unwrap_or(0)
    }
}

struct ActorSim {
    region: usize,
    gpu: GpuClass,
    /// End of the actor's current batch.
    batch_end: f64,
    /// Earliest start for its *next* batch (delta committed).
    next_start: f64,
}

/// Run the simulation.
pub fn run(cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.bench, cfg.trainer_gpus);
    let dense_bytes = cfg.model.dense_bytes_bf16();
    let rho = cfg.model.expected_rho;
    let mut timeline = Timeline::default();

    let rdma = Link::emulated(800e9, 0.000_05, 0.0);
    let ideal = cfg.system == System::IdealSingleDc;
    // Colocated actors fan out over NVLink-class fabric; WAN regions over
    // a 10 Gbps provider LAN.
    let intra = if ideal {
        Link::emulated(7200e9, 0.000_01, 0.0) // NVLink 900 GB/s
    } else {
        intra_region_link()
    };
    let wan_links: Vec<Link> = cfg
        .regions
        .iter()
        .map(|r| {
            if ideal {
                rdma.clone()
            } else {
                Link::from_profile(&r.profile)
            }
        })
        .collect();

    // Payload + plan per system. The PrimeRL baselines inherit PrimeRL's
    // shardcast-style regional relay (one WAN copy per region) so the
    // comparison isolates payload/streams/pipelining, matching §7.1.
    let (payload, plan, pipelined_extract): (u64, TransferPlan, bool) = match cfg.system {
        System::Sparrow => (
            delta_payload_bytes(&cfg.model, rho),
            TransferPlan {
                streams: cfg.streams,
                segment_bytes: 1 << 20,
                pipelined: true,
                jittered: cfg.jittered,
            },
            true,
        ),
        System::PrimeRlFull => (
            dense_bytes,
            TransferPlan { jittered: cfg.jittered, ..TransferPlan::full_weight() },
            false,
        ),
        System::PrimeRlMultiStream => (
            dense_bytes,
            TransferPlan {
                jittered: cfg.jittered,
                ..TransferPlan::full_weight_multistream(cfg.streams)
            },
            false,
        ),
        System::IdealSingleDc => (dense_bytes, TransferPlan::full_weight_multistream(8), false),
    };

    let mut actors: Vec<ActorSim> = Vec::new();
    for (ri, region) in cfg.regions.iter().enumerate() {
        for &gpu in &region.gpus {
            actors.push(ActorSim { region: ri, gpu, batch_end: 0.0, next_start: 0.0 });
        }
    }
    let n = actors.len();
    assert!(n > 0, "no actors configured");

    let mut sched = Scheduler::new(SchedulerConfig::default());
    for (i, a) in actors.iter().enumerate() {
        sched.register(i as u32, cm.rollout_rate(a.gpu, &cfg.model));
        sched.set_region(i as u32, a.region);
    }

    let batch_tokens = cfg.batch as f64 * cm.gen_tokens_per_sample;
    let train_time = cm.train_time(&cfg.model, batch_tokens);
    // Pipelined systems run the fused streaming encoder: emission is the
    // payload produced uniformly over one fused scan pass (measured
    // streaming rate), not the seed's separate extract-then-emit model.
    let extract_time = cm.stream_scan_time(&cfg.model);
    let emit_bps = cm.stream_emit_bps(&cfg.model, payload);

    let mut trainer_free = 0.0f64;
    let mut last_frontier = 0.0f64;
    // Rollouts of the previous window, feeding this window's train step
    // (one-step asynchronous RL: train overlaps the next generation).
    let mut collected_prev = 0.0f64;
    let mut stats: Vec<StepStat> = Vec::new();
    let mut total_gen_tokens = 0u64;

    // Lease window for the failure path: 2.5x the median batch duration.
    let lease_s = 2.5 * SimConfig::TARGET_WINDOW_S;

    for step in 0..cfg.steps {
        // --- split the batch ------------------------------------------
        for i in 0..n {
            sched.observe_version(i as u32, VersionState { active: step, staged: None });
        }
        let shares: Vec<(usize, u64)> = if cfg.hetero_sched {
            let alloc = if cfg.bandwidth_gate {
                sched.allocate_bandwidth_aware(
                    step,
                    cfg.batch,
                    payload,
                    SimConfig::TARGET_WINDOW_S,
                )
            } else {
                sched.allocate(step, cfg.batch)
            };
            alloc
                .into_iter()
                .map(|a| (a.actor as usize, a.requests))
                .collect()
        } else {
            let per = cfg.batch / n as u64;
            let mut v: Vec<(usize, u64)> = (0..n).map(|i| (i, per)).collect();
            for k in 0..(cfg.batch - per * n as u64) as usize {
                v[k % n].1 += 1;
            }
            v
        };

        // --- rollout phase (on the stale policy) -----------------------
        let failed: Vec<usize> = cfg
            .failures
            .iter()
            .filter(|f| f.step == step)
            .map(|f| f.actor)
            .collect();
        let mut collected = 0.0f64;
        let mut window = 0.0f64;
        let mut redo_work = 0u64;
        let mut redo_from = 0.0f64;
        let mut surviving_rate = 0.0f64;
        for &(ai, share) in &shares {
            if share == 0 {
                continue;
            }
            let a = &mut actors[ai];
            let start = a.batch_end.max(a.next_start);
            if failed.contains(&ai) {
                redo_work += share;
                redo_from = redo_from.max(start + lease_s);
                a.batch_end = start + lease_s;
                continue;
            }
            let dur = cm.rollout_time(a.gpu, &cfg.model, share);
            let end = start + dur;
            timeline.record(&format!("actor{ai:02}"), SpanKind::Rollout, start, end, step);
            let res_bytes = share * cm.result_bytes_per_sample();
            let res_t = wan_links[a.region].control_delay()
                + res_bytes as f64 * 8.0 / wan_links[a.region].effective_bps(1);
            a.batch_end = end;
            collected = collected.max(end + res_t);
            window = window.max(dur);
            surviving_rate += cm.rollout_rate(a.gpu, &cfg.model);
            sched.settle(ai as u32, (share as f64 * cm.gen_tokens_per_sample) as u64, dur);
            total_gen_tokens += (share as f64 * cm.gen_tokens_per_sample) as u64;
        }
        if redo_work > 0 && surviving_rate > 0.0 {
            // Lease expiry returns the failed prompts; survivors redo them
            // in parallel, rate-sharing the remainder.
            let redo_t = redo_work as f64 * cm.gen_tokens_per_sample / surviving_rate;
            collected = collected.max(redo_from + redo_t);
            total_gen_tokens += (redo_work as f64 * cm.gen_tokens_per_sample) as u64;
        }

        // --- train (consumes the *previous* window's rollouts, running
        // concurrently with this window's generation) --------------------
        let train_start = collected_prev.max(trainer_free);
        let train_end = train_start + train_time;
        timeline.record("trainer", SpanKind::Train, train_start, train_end, step);
        trainer_free = train_end;
        collected_prev = collected;

        // --- extract + stream the new delta ------------------------------
        let mut max_deliver = train_end;
        for (ri, region) in cfg.regions.iter().enumerate() {
            let wan = &wan_links[ri];
            let members: Vec<usize> = actors
                .iter()
                .enumerate()
                .filter(|(_, a)| a.region == ri)
                .map(|(i, _)| i)
                .collect();
            if members.is_empty() {
                continue;
            }
            let produce = if pipelined_extract { Some(emit_bps) } else { None };
            let deliver_at = if region.use_relay && members.len() > 1 {
                train_end
                    + plan.relay_fanout_time(wan, &intra, payload, members.len() - 1, produce, &mut rng)
            } else {
                train_end + plan.direct_fanout_time(wan, payload, members.len(), produce, &mut rng)
            };
            let deliver_at = deliver_at + wan.control_delay(); // Commit msg
            // Observed distribution throughput feeds the bandwidth gate.
            sched.observe_transfer(ri, payload, (deliver_at - train_end).max(1e-9));
            for &ai in &members {
                // Next batch starts once the running batch ends AND the
                // new version is committed at a safe point.
                actors[ai].next_start = actors[ai].batch_end.max(deliver_at);
            }
            max_deliver = max_deliver.max(deliver_at);
        }
        if pipelined_extract {
            timeline.record(
                "trainer",
                SpanKind::Extract,
                train_end,
                train_end + extract_time,
                step,
            );
        }
        timeline.record("trainer", SpanKind::Transfer, train_end, max_deliver, step);

        // Step cadence: growth of the "next window can start" frontier.
        let frontier = actors
            .iter()
            .map(|a| a.next_start)
            .fold(train_end, f64::max);
        stats.push(StepStat {
            step,
            step_time: frontier - last_frontier,
            transfer_time: max_deliver - train_end,
            payload_bytes: payload,
            rollout_window: window,
            train_time,
        });
        last_frontier = frontier;
    }

    SimResult {
        system: cfg.system,
        steps: stats,
        total_time: last_frontier,
        total_gen_tokens,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{self, regions};

    fn paper_cfg(system: System, model: &str) -> SimConfig {
        let model = config::model(model).unwrap();
        // Actor count scales with model size (paper: 4/8/12 A100s).
        let n_actors = (model.total_params() as f64 / 1.02e9).round() as usize;
        let regions = vec![RegionSpec::new(
            regions::CANADA,
            vec![GpuClass::A100; n_actors.clamp(4, 16)],
        )];
        SimConfig::paper_testbed(model, Benchmark::Gsm8k, system, regions)
    }

    #[test]
    fn sparrow_beats_full_broadcast_qwen3_8b() {
        let sparrow = run(&paper_cfg(System::Sparrow, "qwen3-8b"));
        let full = run(&paper_cfg(System::PrimeRlFull, "qwen3-8b"));
        let speedup = sparrow.throughput() / full.throughput();
        assert!(
            (2.4..11.0).contains(&speedup),
            "sparrow {:.0} vs full {:.0} tok/s (x{speedup:.2})",
            sparrow.throughput(),
            full.throughput()
        );
    }

    #[test]
    fn sparrow_close_to_ideal_single_dc() {
        let sparrow = run(&paper_cfg(System::Sparrow, "qwen3-8b"));
        let ideal = run(&paper_cfg(System::IdealSingleDc, "qwen3-8b"));
        let gap = 1.0 - sparrow.throughput() / ideal.throughput();
        assert!(
            (-0.01..0.15).contains(&gap),
            "gap to ideal {:.1}% (paper: 1.31-8.91%)",
            gap * 100.0
        );
    }

    #[test]
    fn multistream_between_full_and_sparrow() {
        let full = run(&paper_cfg(System::PrimeRlFull, "qwen3-8b")).throughput();
        let ms = run(&paper_cfg(System::PrimeRlMultiStream, "qwen3-8b")).throughput();
        let sparrow = run(&paper_cfg(System::Sparrow, "qwen3-8b")).throughput();
        assert!(ms > full * 1.1, "multistream helps dense transfer");
        assert!(sparrow > ms * 1.2, "sparse deltas beat dense multistream");
    }

    #[test]
    fn gap_to_full_widens_with_model_scale() {
        // Fig 8: 4B speedup 2.4-3.7x, 14B speedup 7.7-9.5x.
        let ratio = |m: &str| {
            run(&paper_cfg(System::Sparrow, m)).throughput()
                / run(&paper_cfg(System::PrimeRlFull, m)).throughput()
        };
        let s4 = ratio("qwen3-4b");
        let s14 = ratio("qwen3-14b");
        assert!(s14 > 1.8 * s4, "4B x{s4:.1} vs 14B x{s14:.1}");
        assert!((2.0..5.0).contains(&s4), "4B x{s4:.1} (paper 2.4-3.7)");
        assert!((6.5..13.0).contains(&s14), "14B x{s14:.1} (paper 7.7-9.5)");
    }

    #[test]
    fn failure_recovers_via_lease_redistribution() {
        let mut cfg = paper_cfg(System::Sparrow, "qwen3-8b");
        cfg.failures = vec![FailureEvent { actor: 0, step: 2 }];
        let with_failure = run(&cfg);
        let healthy = run(&paper_cfg(System::Sparrow, "qwen3-8b"));
        assert_eq!(with_failure.total_gen_tokens, healthy.total_gen_tokens);
        assert!(with_failure.total_time > healthy.total_time);
        assert!(
            with_failure.total_time
                < healthy.total_time + 2.5 * SimConfig::TARGET_WINDOW_S + 90.0,
            "failure overhead bounded by the lease window"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(&paper_cfg(System::Sparrow, "qwen3-8b"));
        let b = run(&paper_cfg(System::Sparrow, "qwen3-8b"));
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.total_gen_tokens, b.total_gen_tokens);
    }

    #[test]
    fn timeline_records_all_span_kinds() {
        let r = run(&paper_cfg(System::Sparrow, "qwen3-8b"));
        assert!(r.timeline.total("trainer", SpanKind::Train) > 0.0);
        assert!(r.timeline.total("trainer", SpanKind::Transfer) > 0.0);
        assert!(r.timeline.total("actor00", SpanKind::Rollout) > 0.0);
    }

    #[test]
    fn bandwidth_gate_preserves_batch_and_determinism() {
        let model = config::model("qwen3-8b").unwrap();
        let fleet = vec![
            RegionSpec::new(regions::CANADA, vec![GpuClass::A100; 4]),
            RegionSpec::new(regions::AUSTRALIA, vec![GpuClass::A100; 4]),
        ];
        let mut cfg =
            SimConfig::paper_testbed(model, Benchmark::Gsm8k, System::Sparrow, fleet);
        cfg.bandwidth_gate = true;
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.total_gen_tokens, b.total_gen_tokens, "gate is deterministic");
        assert_eq!(a.total_time, b.total_time);
        let mut off = cfg.clone();
        off.bandwidth_gate = false;
        let base = run(&off);
        assert_eq!(
            a.total_gen_tokens, base.total_gen_tokens,
            "the gate reallocates work, it never drops any"
        );
    }

    #[test]
    fn hetero_scheduling_beats_uniform_on_mixed_pool() {
        // Table 7's setting: mixed A100+L40 pool.
        let model = config::model("qwen3-4b").unwrap();
        let mk = |hetero: bool| {
            let regions = vec![RegionSpec::new(
                regions::CANADA,
                vec![
                    GpuClass::A100,
                    GpuClass::A100,
                    GpuClass::A100,
                    GpuClass::A100,
                    GpuClass::L40,
                    GpuClass::L40,
                    GpuClass::L40,
                    GpuClass::L40,
                ],
            )];
            let mut cfg = SimConfig::paper_testbed(
                model.clone(),
                Benchmark::Gsm8k,
                System::Sparrow,
                regions,
            );
            // Table 7's trainer (4xH100) keeps training off the critical
            // path so the scheduling effect is visible.
            cfg.trainer_gpus = 4;
            cfg.hetero_sched = hetero;
            cfg
        };
        let aware = run(&mk(true)).throughput();
        let uniform = run(&mk(false)).throughput();
        let gain = aware / uniform - 1.0;
        assert!(
            (0.10..0.50).contains(&gain),
            "hetero gain {:.1}% (paper: 26.4-35.5%)",
            gain * 100.0
        );
    }
}
