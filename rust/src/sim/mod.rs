//! End-to-end system simulator: the §7 geo-distributed testbed as a
//! deterministic virtual-time model.
//!
//! The RL loop has a fixed pipeline structure (rollout ‖ train ‖ transfer
//! under a one-step policy lag), so the simulator advances step-by-step
//! computing entity completion times from the calibrated compute model
//! (`compute.rs`) and the netsim link models, while reusing the *real*
//! scheduler (Algorithm 1) for batch splitting. Systems differ only in the
//! knobs the paper varies — payload (sparse vs dense), transfer plan
//! (streams / pipelining / relay), and link fabric (WAN vs RDMA):
//!
//! | system               | payload      | plan                | fabric |
//! |----------------------|--------------|---------------------|--------|
//! | SparrowRL            | sparse delta | multi-stream + relay| WAN    |
//! | PrimeRL-Full         | dense bf16   | single stream       | WAN    |
//! | PrimeRL-MultiStream  | dense bf16   | S streams           | WAN    |
//! | Ideal-SingleDC       | dense bf16   | RDMA broadcast      | RDMA   |

pub mod compute;
pub mod driver;

pub use compute::ComputeModel;
pub use driver::{SimConfig, SimResult, StepStat};

use crate::config::RegionProfile;
use crate::config::GpuClass;

/// Which RL system is being simulated (§7.1 baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    /// Sparse deltas, pipelined extraction, S streams, relay fanout.
    Sparrow,
    /// Dense full-weight broadcast over one TCP stream per actor.
    PrimeRlFull,
    /// Dense weights chunked over S parallel streams.
    PrimeRlMultiStream,
    /// Trainer + actors colocated on an RDMA fabric (upper bound).
    IdealSingleDc,
}

impl System {
    pub fn name(self) -> &'static str {
        match self {
            System::Sparrow => "SparrowRL",
            System::PrimeRlFull => "PrimeRL-Full",
            System::PrimeRlMultiStream => "PrimeRL-MS",
            System::IdealSingleDc => "Ideal-SingleDC",
        }
    }

    pub fn all() -> [System; 4] {
        [
            System::IdealSingleDc,
            System::Sparrow,
            System::PrimeRlMultiStream,
            System::PrimeRlFull,
        ]
    }
}

/// One region of rollout actors and its WAN path from the Trainer.
#[derive(Clone, Debug)]
pub struct RegionSpec {
    pub profile: RegionProfile,
    pub gpus: Vec<GpuClass>,
    /// Route deltas through a regional relay (vs direct per-actor send).
    pub use_relay: bool,
}

impl RegionSpec {
    pub fn new(profile: RegionProfile, gpus: Vec<GpuClass>) -> RegionSpec {
        RegionSpec { profile, gpus, use_relay: true }
    }
}
