//! Calibrated compute + payload model for the simulator.
//!
//! Calibration anchors (all from the paper):
//! * Table 2: Qwen3-8B — trainer step ~40 s, rollout window ~45 s.
//! * §5.2: delta extraction ~5 s for the 16 GB model => ~3.2 GB/s scan.
//! * §7.3: delta payload 202 MB at rho=0.96% (varint), 414 MB naive.
//! * §5.3: A100 ~2500 tokens/s on an ~8B policy; H100 2x that.

use crate::config::{GpuClass, ModelSpec};
use crate::data::Benchmark;

/// Reference model size for the per-GPU token-rate priors.
const REF_PARAMS: f64 = 8.2e9;
/// Trainer anchor: seconds per optimizer step for 8B on 4 H100s at the
/// reference batch of 900k trained tokens (Table 2's ~40 s step).
const TRAIN_ANCHOR_S: f64 = 40.0;
const TRAIN_ANCHOR_PARAMS: f64 = 8.2e9;
const TRAIN_ANCHOR_GPUS: f64 = 4.0;
pub const TRAIN_ANCHOR_TOKENS: f64 = 900e3;
/// Dense-parameter scan rate of the seed's two-pass extract-then-encode
/// pipeline (bytes/s). Kept as the paper's ~5 s / 16 GB anchor.
pub const EXTRACT_SCAN_BPS: f64 = 3.2e9;
/// Dense-parameter scan rate of the fused single-pass streaming encoder
/// (`delta/stream.rs`), bytes/s. Fusing extract+encode+segment removes the
/// re-walk and copy passes, sustaining ~2x the two-pass pipeline's
/// effective source rate (measured by `rust/benches/encoding.rs`; tracked
/// across PRs in BENCH_encoding.json).
pub const STREAM_ENCODE_BPS: f64 = 6.4e9;

/// Everything duration-related the driver needs.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    /// Mean generated tokens per rollout sample (benchmark-dependent).
    pub gen_tokens_per_sample: f64,
    /// Prompt tokens per sample (context, not produced).
    pub prompt_tokens: f64,
    /// Trainer H100 count.
    pub trainer_gpus: usize,
}

impl ComputeModel {
    pub fn new(bench: Benchmark, trainer_gpus: usize) -> ComputeModel {
        // Longer-form reasoning benchmarks produce longer rollouts
        // (DeepScaleR's long-tail is the paper's motivation for leases).
        let gen_tokens_per_sample = match bench {
            Benchmark::Gsm8k => 300.0,
            Benchmark::Math => 450.0,
            Benchmark::DeepScaleR => 600.0,
        };
        ComputeModel { gen_tokens_per_sample, prompt_tokens: 64.0, trainer_gpus }
    }

    /// Rollout generation rate for one actor GPU on this model, tokens/s.
    /// Inversely proportional to parameter count around the 8B anchors.
    pub fn rollout_rate(&self, gpu: GpuClass, model: &ModelSpec) -> f64 {
        gpu.rollout_tokens_per_s() * (REF_PARAMS / model.total_params() as f64)
    }

    /// Wall time for one actor to generate `samples` rollouts.
    pub fn rollout_time(&self, gpu: GpuClass, model: &ModelSpec, samples: u64) -> f64 {
        samples as f64 * self.gen_tokens_per_sample / self.rollout_rate(gpu, model)
    }

    /// Trainer optimizer-step time (fwd+bwd+update): linear in parameter
    /// count and in the step's trained-token count, inverse in GPUs.
    pub fn train_time(&self, model: &ModelSpec, batch_tokens: f64) -> f64 {
        TRAIN_ANCHOR_S * (model.total_params() as f64 / TRAIN_ANCHOR_PARAMS)
            * (TRAIN_ANCHOR_GPUS / self.trainer_gpus as f64)
            * (batch_tokens / TRAIN_ANCHOR_TOKENS)
    }

    /// CPU extraction time of the legacy two-pass pipeline: dense scan of
    /// the bf16 snapshot, then a separate encode pass.
    pub fn extract_time(&self, model: &ModelSpec) -> f64 {
        model.dense_bytes_bf16() as f64 / EXTRACT_SCAN_BPS
    }

    /// Wall time of the fused streaming scan (extract+encode+segment in
    /// one pass at `STREAM_ENCODE_BPS`).
    pub fn stream_scan_time(&self, model: &ModelSpec) -> f64 {
        model.dense_bytes_bf16() as f64 / STREAM_ENCODE_BPS
    }

    /// Source rate of the fused streaming pipeline (bits/s): the encoder
    /// emits payload bytes in proportion to scan progress over one fused
    /// pass, so cut-through forwarding sees the payload produced uniformly
    /// across `stream_scan_time`. This replaces the seed's separate
    /// extract-then-emit burst model (`extract_emit_bps`) for every
    /// pipelined system.
    pub fn stream_emit_bps(&self, model: &ModelSpec, payload_bytes: u64) -> f64 {
        payload_bytes as f64 * 8.0 / self.stream_scan_time(model).max(1e-9)
    }

    /// Rate at which encoded delta bytes are produced during extraction
    /// (bits/s) under the *legacy* two-pass pipeline. Emission is bursty:
    /// the scan walks the fused layout in order and the big MLP
    /// projections (most of the nonzeros) materialize in the later half,
    /// so the effective source rate seen by cut-through forwarding is ~2x
    /// the payload/scan-time mean. Kept for ablation against the fused
    /// model (the two happen to coincide numerically: fusing doubles the
    /// sustained scan rate, burstiness doubled the effective rate).
    pub fn extract_emit_bps(&self, model: &ModelSpec, payload_bytes: u64) -> f64 {
        payload_bytes as f64 * 8.0 / (0.5 * self.extract_time(model)).max(1e-9)
    }

    /// Result-return bytes per sample (tokens at 4 B + metadata).
    pub fn result_bytes_per_sample(&self) -> u64 {
        (self.gen_tokens_per_sample as u64) * 4 + 256
    }
}

/// Expected LEB128 bytes per gap at nonzero density `rho` (gaps are
/// ~Geometric(rho); len >= k+1 iff gap >= 128^k).
pub fn leb128_bytes_per_index(rho: f64) -> f64 {
    let q = 1.0 - rho;
    1.0 + q.powi(128) + q.powi(16384)
}

/// Sparse delta payload in bytes for a model at density `rho`, using the
/// varint codec (2-byte bf16 value + gap-coded index + ~2% framing).
pub fn delta_payload_bytes(model: &ModelSpec, rho: f64) -> u64 {
    let nnz = model.total_params() as f64 * rho;
    (nnz * (2.0 + leb128_bytes_per_index(rho)) * 1.02) as u64
}

/// Naive fixed-width payload (Figure 10 baseline): int32/int64 + bf16.
/// Width follows the *per-tensor* index space (the fused layout keeps
/// every tensor below 2^32 elements, so int32 indices suffice).
pub fn naive_payload_bytes(model: &ModelSpec, rho: f64) -> u64 {
    let nnz = model.total_params() as f64 * rho;
    let max_tensor = model
        .layout
        .tensors
        .iter()
        .map(|t| t.numel())
        .max()
        .unwrap_or(0);
    let idx = if max_tensor <= u32::MAX as u64 { 4.0 } else { 8.0 };
    (nnz * (idx + 2.0)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    #[test]
    fn anchors_match_paper_table2() {
        let model = config::model("qwen3-8b").unwrap();
        let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
        assert!((cm.train_time(&model, TRAIN_ANCHOR_TOKENS) - 40.0).abs() < 1.0);
        let ext = cm.extract_time(&model);
        assert!((4.5..6.0).contains(&ext), "extract {ext:.1}s (paper ~5s)");
    }

    #[test]
    fn rollout_rates_scale_with_model_size() {
        let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
        let m8 = config::model("qwen3-8b").unwrap();
        let m4 = config::model("qwen3-4b").unwrap();
        let a100_8b = cm.rollout_rate(GpuClass::A100, &m8);
        assert!((2400.0..2600.0).contains(&a100_8b), "{a100_8b}");
        assert!(cm.rollout_rate(GpuClass::A100, &m4) > 1.9 * a100_8b);
    }

    #[test]
    fn payload_sizes_match_paper_figure10_shape() {
        // Qwen3-8B at rho=0.96%: paper varint 202 MB, naive 414 MB.
        // Our codec spends ~3.3 B/nnz, i.e. ~265 MB — same order, and the
        // naive/varint ratio (the ablation's point) must land near 2x.
        let model = config::model("qwen3-8b").unwrap();
        let varint = delta_payload_bytes(&model, 0.0096) as f64;
        let naive = naive_payload_bytes(&model, 0.0096) as f64;
        assert!((180e6..300e6).contains(&varint), "varint {:.0} MB", varint / 1e6);
        assert!((400e6..520e6).contains(&naive), "naive {:.0} MB", naive / 1e6);
        let cut = 1.0 - varint / naive;
        assert!((0.30..0.55).contains(&cut), "varint cut {:.2}", cut);
    }

    #[test]
    fn payload_reduction_vs_dense_is_tens_of_x() {
        // Paper headline: 79x payload reduction for Qwen3-8B.
        let model = config::model("qwen3-8b").unwrap();
        let ratio = model.dense_bytes_bf16() as f64
            / delta_payload_bytes(&model, 0.0096) as f64;
        assert!((40.0..90.0).contains(&ratio), "reduction {ratio:.0}x");
    }

    #[test]
    fn leb128_expectation_is_monotone_in_density() {
        assert!(leb128_bytes_per_index(0.001) > leb128_bytes_per_index(0.01));
        assert!(leb128_bytes_per_index(0.01) > leb128_bytes_per_index(0.5));
        assert!(leb128_bytes_per_index(0.5) >= 1.0);
    }

    #[test]
    fn extraction_emit_rate_is_bursty_half_scan() {
        let model = config::model("qwen3-8b").unwrap();
        let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
        let payload = delta_payload_bytes(&model, 0.0096);
        let bps = cm.extract_emit_bps(&model, payload);
        let t = payload as f64 * 8.0 / bps;
        assert!((t - 0.5 * cm.extract_time(&model)).abs() < 1e-6);
    }

    #[test]
    fn stream_emit_rate_is_uniform_over_fused_scan() {
        let model = config::model("qwen3-8b").unwrap();
        let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
        let payload = delta_payload_bytes(&model, 0.0096);
        let bps = cm.stream_emit_bps(&model, payload);
        // Payload over the fused scan duration, exactly.
        let t = payload as f64 * 8.0 / bps;
        assert!((t - cm.stream_scan_time(&model)).abs() < 1e-6);
        // The fused pass halves the scan wall time (one pass, no re-walk).
        assert!(cm.stream_scan_time(&model) < 0.51 * cm.extract_time(&model));
        // And its sustained source rate is at least the legacy pipeline's
        // bursty effective rate.
        assert!(bps >= cm.extract_emit_bps(&model, payload) * 0.999);
    }
}
