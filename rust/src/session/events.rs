//! The typed event stream a live [`Session`](crate::session::Session)
//! exposes, and the assembler that folds it back into a
//! [`RunReport`] — the single source of truth for both: the report is
//! *derived from* the events, so the two cannot disagree.

use crate::rt::{RunReport, StepLog};
use crate::metrics::Timeline;

/// One observable moment of a running session, in emission order:
/// `SftStep*` (warmup), then per RL version `DeltaStreamed` →
/// `Committed` → `StepCompleted`, with `Failover` interleaved whenever an
/// actor is lost, and `Finished` as the final event of a successful run.
///
/// All events are emitted by the trainer hub's thread; a `Session`
/// delivers them through `recv()`/`try_iter()` on the caller's thread.
#[derive(Clone, Debug)]
pub enum Event {
    /// One supervised warmup step completed.
    SftStep { step: u64, loss: f32 },
    /// A full RL step closed its books: generation, training, and the
    /// delta extraction for the version it produced are all accounted.
    StepCompleted(StepLog),
    /// The fused extract→encode→segment pass shipped `D_{version}` into
    /// the transport fan-out: `payload_bytes` on the wire, cut into
    /// `stripes` segments (the stripe granularity backends reorder at).
    DeltaStreamed { version: u64, payload_bytes: u64, stripes: u64 },
    /// The trainer committed `version`; `checksum` is the SHA-256 policy
    /// witness every actor must echo in its `Activated` ack.
    Committed { version: u64, checksum: [u8; 32] },
    /// Lease-driven failover absorbed a lost actor: `requeued` of its
    /// leased prompts were re-issued to survivors (original order + RNG
    /// seed, so regeneration is bit-identical).
    Failover { actor: u32, requeued: u64 },
    /// The run completed; the report was assembled from this very event
    /// stream (by the crate-internal `ReportAssembler`).
    Finished(RunReport),
}

/// What the runtime hands back besides the event stream: the bits of a
/// [`RunReport`] that are not step-shaped (and so have no event).
#[derive(Clone, Debug)]
pub struct RunTail {
    pub final_version: u64,
    pub wall_s: f64,
    pub timeline: Timeline,
}

/// Folds the event stream into a [`RunReport`]. Both the blocking legacy
/// API (`rt::run_with_compute`) and `Session::join` build their reports
/// through this type, so a report can never claim something its event
/// stream did not say.
#[derive(Default)]
pub(crate) struct ReportAssembler {
    sft_losses: Vec<f32>,
    steps: Vec<StepLog>,
    failovers: u64,
    requeued: u64,
}

impl ReportAssembler {
    pub(crate) fn record(&mut self, ev: &Event) {
        match ev {
            Event::SftStep { loss, .. } => self.sft_losses.push(*loss),
            Event::StepCompleted(log) => self.steps.push(*log),
            Event::Failover { requeued, .. } => {
                self.failovers += 1;
                self.requeued += *requeued;
            }
            Event::DeltaStreamed { .. } | Event::Committed { .. } | Event::Finished(_) => {}
        }
    }

    pub(crate) fn finish(self, tail: RunTail) -> RunReport {
        RunReport {
            sft_losses: self.sft_losses,
            steps: self.steps,
            final_version: tail.final_version,
            wall_s: tail.wall_s,
            timeline: tail.timeline,
            failovers: self.failovers,
            requeued_prompts: self.requeued,
        }
    }
}
