//! The typed event stream a live [`Session`](crate::session::Session)
//! exposes, and the assembler that folds it back into a
//! [`RunReport`] — the single source of truth for both: the report is
//! *derived from* the events, so the two cannot disagree.

use crate::cost::ScaleDecision;
use crate::metrics::Timeline;
use crate::rt::{BootstrapKind, FailReason, RunReport, StepLog};

/// One observable moment of a running session, in emission order:
/// `SftStep*` (warmup), then per RL version `DeltaStreamed` →
/// `Committed` → `StepCompleted`, with membership events (`Joined`,
/// `Draining`, `Preempted`, `Failover`, `Autoscale`) interleaved as the
/// fleet changes, and `Finished` as the final event of a successful run.
///
/// All events are emitted by the trainer hub's thread; a `Session`
/// delivers them through `recv()`/`try_iter()` on the caller's thread.
#[derive(Clone, Debug)]
pub enum Event {
    /// One supervised warmup step completed.
    SftStep { step: u64, loss: f32 },
    /// A full RL step closed its books: generation, training, and the
    /// delta extraction for the version it produced are all accounted.
    StepCompleted(StepLog),
    /// The fused extract→encode→segment pass shipped `D_{version}` into
    /// the transport fan-out: `payload_bytes` on the wire, cut into
    /// `stripes` segments (the stripe granularity backends reorder at).
    DeltaStreamed { version: u64, payload_bytes: u64, stripes: u64 },
    /// The trainer committed `version`; `checksum` is the SHA-256 policy
    /// witness every actor must echo in its `Activated` ack.
    Committed { version: u64, checksum: [u8; 32] },
    /// A new actor was admitted mid-run: bootstrapped to `version` via
    /// `bootstrap` (`bytes` on the wire), its SHA-256 policy witness
    /// verified against the hub's, then entered into the scheduler.
    Joined { actor: u32, version: u64, bootstrap: BootstrapKind, bytes: u64 },
    /// An actor departed gracefully: its leased prompts (if any) were
    /// handed back and re-issued without a failover penalty.
    Draining { actor: u32, requeued: u64 },
    /// A spot-preemption warning arrived: the actor announced it is
    /// about to be reclaimed. The hub stops scheduling it; if the kill
    /// lands before its leases settle, the `Failover` that follows
    /// carries `FailReason::Preempted`.
    Preempted { actor: u32 },
    /// Lease-driven failover absorbed a lost actor: `requeued` of its
    /// leased prompts were re-issued to survivors (original order + RNG
    /// seed, so regeneration is bit-identical). `reason` is the typed
    /// cause — graceful drains never appear here.
    Failover { actor: u32, requeued: u64, reason: FailReason },
    /// The cost-model autoscaler evaluated the fleet at a step boundary
    /// and emitted a typed decision (advisory; see `cost::Autoscaler`).
    Autoscale { version: u64, decision: ScaleDecision },
    /// A run-epilogue hot-swap retargeted `actor` onto the published
    /// fine-tune `model@version` (registry numbering) by shipping the
    /// composed swap delta (`bytes` on the wire) through the ordinary
    /// staging machinery; the actor's post-swap checksum matched the
    /// registry's published witness.
    Swapped { actor: u32, model: String, version: u64, bytes: u64 },
    /// The run completed; the report was assembled from this very event
    /// stream (by the crate-internal `ReportAssembler`).
    Finished(RunReport),
}

/// What the runtime hands back besides the event stream: the bits of a
/// [`RunReport`] that are not step-shaped (and so have no event).
#[derive(Clone, Debug)]
pub struct RunTail {
    pub final_version: u64,
    pub wall_s: f64,
    pub timeline: Timeline,
}

/// Folds the event stream into a [`RunReport`]. Both the blocking legacy
/// API (`rt::run_with_compute`) and `Session::join` build their reports
/// through this type, so a report can never claim something its event
/// stream did not say.
#[derive(Default)]
pub(crate) struct ReportAssembler {
    sft_losses: Vec<f32>,
    steps: Vec<StepLog>,
    failovers: u64,
    requeued: u64,
    joins: u64,
    drains: u64,
    preempts: u64,
    swaps: u64,
}

impl ReportAssembler {
    pub(crate) fn record(&mut self, ev: &Event) {
        match ev {
            Event::SftStep { loss, .. } => self.sft_losses.push(*loss),
            Event::StepCompleted(log) => self.steps.push(*log),
            Event::Failover { requeued, .. } => {
                self.failovers += 1;
                self.requeued += *requeued;
            }
            Event::Joined { .. } => self.joins += 1,
            Event::Draining { requeued, .. } => {
                self.drains += 1;
                self.requeued += *requeued;
            }
            Event::Preempted { .. } => self.preempts += 1,
            Event::Swapped { .. } => self.swaps += 1,
            Event::DeltaStreamed { .. }
            | Event::Committed { .. }
            | Event::Autoscale { .. }
            | Event::Finished(_) => {}
        }
    }

    pub(crate) fn finish(self, tail: RunTail) -> RunReport {
        RunReport {
            sft_losses: self.sft_losses,
            steps: self.steps,
            final_version: tail.final_version,
            wall_s: tail.wall_s,
            timeline: tail.timeline,
            failovers: self.failovers,
            requeued_prompts: self.requeued,
            joins: self.joins,
            drains: self.drains,
            preempts: self.preempts,
            swaps: self.swaps,
        }
    }
}
