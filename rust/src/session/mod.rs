//! Session API: the embeddable, observable entry point to the runtime.
//!
//! Two types replace the old batch `run_local_mode` surface:
//!
//! * [`RunSpec`] — a builder that owns **all** run configuration
//!   (executor mode, transport backend, WAN distribution, lease policy,
//!   determinism) and whose [`RunSpec::build`] performs every cross-field
//!   legality check in one place: illegal combinations come back as typed
//!   [`SpecError`]s, legal auto-coercions (wan → pipelined, wan → actor
//!   count, wan → relay tree) as typed [`SpecNote`]s on the [`RunPlan`].
//! * [`Session`] — [`Session::start`] (PJRT artifacts) or
//!   [`Session::start_with_compute`] (any [`Compute`](crate::rt::Compute)
//!   backend, e.g. [`SyntheticCompute`](crate::rt::SyntheticCompute))
//!   runs the executor on a background thread and hands back a handle
//!   exposing the typed [`Event`] stream, a cooperative
//!   [`Session::abort`], and [`Session::join`]` -> RunReport` — the
//!   report assembled *from* the event stream, so the two cannot
//!   disagree.
//!
//! This is the seam every long-running deployment plugs into: live
//! dashboards subscribe to `Event`s, controllers `abort()` and resubmit
//! refined specs, and the CLI is just one more subscriber (see
//! `main.rs::cmd_train`). Architecture notes: docs/ARCHITECTURE.md §2c.
//!
//! ```
//! use sparrowrl::session::{RunSpec, SpecNote};
//! use sparrowrl::rt::ExecMode;
//! use sparrowrl::trainer::Algorithm;
//!
//! // A 2-region WAN run: the builder derives the fleet size and relay
//! // tree from the preset and coerces the executor to pipelined —
//! // surfacing both as typed notes instead of printing.
//! let plan = RunSpec::model("sparrow-xs")
//!     .algorithm(Algorithm::Grpo)
//!     .steps(3)
//!     .wan("wan-2")
//!     .build()
//!     .expect("legal spec");
//! assert_eq!(plan.mode(), ExecMode::Pipelined);
//! assert_eq!(plan.config().n_actors, 4); // wan-2: 2 regions x 2 actors
//! assert!(plan
//!     .notes()
//!     .iter()
//!     .any(|n| matches!(n, SpecNote::PipelinedCoerced { .. })));
//!
//! // Illegal combinations are typed errors, not deep-runtime bails:
//! let err = RunSpec::model("sparrow-xs").wan("wan-2").actors(3).build();
//! assert!(err.is_err());
//! ```

mod events;
mod handle;
mod spec;

pub use events::Event;
pub(crate) use events::{ReportAssembler, RunTail};
pub use handle::{Session, SessionProbe, SessionStatus, ABORT_MSG};
pub use spec::{Backend, RunPlan, RunSpec, SpecError, SpecNote};
