//! [`Session`]: a live handle on a running training loop.
//!
//! `Session::start` launches the executor on a background thread and
//! returns immediately; the caller observes the run through the typed
//! [`Event`] stream (`recv`/`try_recv`/`try_iter`), can `abort()` it
//! cooperatively, and `join()`s for the final [`RunReport`] — which is
//! assembled *from the event stream itself*, so the two cannot disagree.

use super::events::{Event, ReportAssembler};
use super::spec::RunPlan;
use crate::delta::ModelLayout;
use crate::rt::pipeline::run_observed;
use crate::rt::{Compute, ExecMode, LocalRunConfig, RunReport};
use crate::runtime::Engines;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// The message `abort()` makes the runtime fail with; surfaced through
/// [`Session::join`]'s error.
pub const ABORT_MSG: &str = "session aborted by caller";

/// A running SparrowRL training session.
///
/// Threading model: one background thread runs the trainer hub (and, in
/// pipelined mode, spawns the scoped actor-worker threads beneath it —
/// they can never outlive the hub). Events flow hub → handle over an
/// unbounded channel, so the runtime never blocks on a slow subscriber.
/// Dropping an unjoined `Session` aborts the run and joins the thread —
/// a session cannot leak a running loop.
pub struct Session {
    rx: Receiver<Event>,
    cancel: Arc<AtomicBool>,
    thread: Option<JoinHandle<Result<super::events::RunTail>>>,
    asm: Option<ReportAssembler>,
    finished: Option<RunReport>,
    error: Option<anyhow::Error>,
}

impl Session {
    /// Start a run on the plan's PJRT artifacts (`make artifacts`).
    /// Synthetic plans have no artifacts — pair them with
    /// [`Session::start_with_compute`].
    pub fn start(plan: &RunPlan) -> Result<Session> {
        if plan.synthetic {
            bail!("a synthetic RunSpec has no artifacts; use Session::start_with_compute");
        }
        let spec = crate::config::model(&plan.cfg.model)
            .with_context(|| format!("unknown model {}", plan.cfg.model))?;
        let eng = Engines::load(&crate::runtime::artifacts_dir(), &plan.cfg.model)?;
        Session::spawn(plan.cfg.clone(), spec.layout.clone(), eng, plan.mode)
    }

    /// Start a run on a caller-supplied compute backend (synthetic or
    /// otherwise); `layout` must match the backend's parameter geometry.
    pub fn start_with_compute<C: Compute + Send + 'static>(
        plan: &RunPlan,
        layout: ModelLayout,
        comp: C,
    ) -> Result<Session> {
        Session::spawn(plan.cfg.clone(), layout, comp, plan.mode)
    }

    /// The engine under both `start` flavors and the deprecated
    /// `rt::run_local_mode` shim.
    pub(crate) fn spawn<C: Compute + Send + 'static>(
        cfg: LocalRunConfig,
        layout: ModelLayout,
        comp: C,
        mode: ExecMode,
    ) -> Result<Session> {
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel_flag = cancel.clone();
        let thread = std::thread::Builder::new()
            .name("sparrowrl-session".to_string())
            .spawn(move || {
                let mut sink = |ev: Event| {
                    // A dropped handle only means nobody is listening;
                    // the run itself is cancelled via the abort flag.
                    let _ = tx.send(ev);
                };
                run_observed(&cfg, &layout, &comp, mode, &mut sink, &cancel_flag)
            })
            .map_err(|e| anyhow!("spawn session thread: {e}"))?;
        Ok(Session {
            rx,
            cancel,
            thread: Some(thread),
            asm: Some(ReportAssembler::default()),
            finished: None,
            error: None,
        })
    }

    /// Blocking: the next event, or `None` once the stream is exhausted
    /// (after [`Event::Finished`] on success; immediately on failure —
    /// the error then comes out of [`Session::join`]).
    pub fn recv(&mut self) -> Option<Event> {
        if self.finished.is_some() {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                if let Some(asm) = self.asm.as_mut() {
                    asm.record(&ev);
                }
                Some(ev)
            }
            Err(_) => self.finish_event(),
        }
    }

    /// Non-blocking: the next event if one is ready.
    pub fn try_recv(&mut self) -> Option<Event> {
        if self.finished.is_some() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                if let Some(asm) = self.asm.as_mut() {
                    asm.record(&ev);
                }
                Some(ev)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => self.finish_event(),
        }
    }

    /// Non-blocking drain of everything currently available.
    pub fn try_iter(&mut self) -> impl Iterator<Item = Event> + '_ {
        std::iter::from_fn(move || self.try_recv())
    }

    /// Ask the run to stop at its next cancellation point (step
    /// boundaries and the collect loop's poll ticks). Cooperative and
    /// idempotent; `join()` then returns the abort error.
    pub fn abort(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Wait for the run to finish and return its report (assembled from
    /// the event stream). Consumes the session; events not yet consumed
    /// are drained (and folded into the report) on the way.
    pub fn join(mut self) -> Result<RunReport> {
        while self.recv().is_some() {}
        if let Some(report) = self.finished.take() {
            return Ok(report);
        }
        Err(self
            .error
            .take()
            .unwrap_or_else(|| anyhow!("session ended without a result")))
    }

    /// The channel closed: the runtime returned. Join the thread and
    /// either synthesize the terminal [`Event::Finished`] (success) or
    /// record the error for [`Session::join`].
    fn finish_event(&mut self) -> Option<Event> {
        let handle = self.thread.take()?;
        match handle.join() {
            Ok(Ok(tail)) => {
                let report = self.asm.take()?.finish(tail);
                self.finished = Some(report.clone());
                Some(Event::Finished(report))
            }
            Ok(Err(e)) => {
                self.error = Some(e);
                None
            }
            Err(_) => {
                self.error = Some(anyhow!("session thread panicked"));
                None
            }
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if let Some(handle) = self.thread.take() {
            self.cancel.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
    }
}
