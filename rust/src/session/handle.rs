//! [`Session`]: a live handle on a running training loop.
//!
//! `Session::start` launches the executor on a background thread and
//! returns immediately; the caller observes the run through the typed
//! [`Event`] stream (`recv`/`try_recv`/`try_iter`), can `abort()` it
//! cooperatively, and `join()`s for the final [`RunReport`] — which is
//! assembled *from the event stream itself*, so the two cannot disagree.

use super::events::{Event, ReportAssembler};
use super::spec::RunPlan;
use crate::delta::ModelLayout;
use crate::rt::pipeline::run_observed;
use crate::rt::{Compute, ExecMode, LocalRunConfig, RunReport};
use crate::runtime::Engines;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// The message `abort()` makes the runtime fail with; surfaced through
/// [`Session::join`]'s error.
pub const ABORT_MSG: &str = "session aborted by caller";

/// Non-blocking snapshot of where a session is, without consuming its
/// event stream or blocking on `join()`. The runtime thread itself keeps
/// this current (progress as events flow through the sink, the terminal
/// state the instant the executor returns), so a poller — e.g. the
/// `daemon` registry — can watch many sessions cheaply.
#[derive(Clone, Debug, PartialEq)]
pub enum SessionStatus {
    /// The run is live: `step` RL steps closed so far, `version` is the
    /// last policy version the trainer committed.
    Running { step: u64, version: u64 },
    /// The executor returned successfully (the terminal
    /// [`Event::Finished`] may not have been consumed yet).
    Finished,
    /// The executor stopped at a cancellation point after
    /// [`Session::abort`].
    Aborted,
    /// The executor failed; `reason` is the rendered error chain.
    Failed { reason: String },
}

impl SessionStatus {
    /// Stable lowercase tag (`running` / `finished` / `aborted` /
    /// `failed`) — what the daemon's JSON snapshots carry.
    pub fn name(&self) -> &'static str {
        match self {
            SessionStatus::Running { .. } => "running",
            SessionStatus::Finished => "finished",
            SessionStatus::Aborted => "aborted",
            SessionStatus::Failed { .. } => "failed",
        }
    }

    /// Terminal-state probe: true for `Finished`, `Aborted`, `Failed`.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, SessionStatus::Running { .. })
    }
}

/// Shared between the runtime thread (writer) and any pollers (readers).
#[derive(Debug)]
pub(crate) struct StatusCell(Mutex<SessionStatus>);

impl StatusCell {
    fn new() -> StatusCell {
        StatusCell(Mutex::new(SessionStatus::Running { step: 0, version: 0 }))
    }

    fn get(&self) -> SessionStatus {
        self.0.lock().expect("status cell poisoned").clone()
    }

    /// Track live progress from the event flow (called by the runtime
    /// thread's sink before each event is forwarded).
    fn observe(&self, ev: &Event) {
        let mut s = self.0.lock().expect("status cell poisoned");
        if let SessionStatus::Running { step, version } = &mut *s {
            match ev {
                Event::StepCompleted(log) => *step = log.step,
                Event::Committed { version: v, .. } => *version = *v,
                _ => {}
            }
        }
    }

    /// Record the terminal state the moment the executor returns.
    fn finish(&self, result: &Result<super::events::RunTail>) {
        let mut s = self.0.lock().expect("status cell poisoned");
        *s = match result {
            Ok(_) => SessionStatus::Finished,
            Err(e) if format!("{e:#}").contains(ABORT_MSG) => SessionStatus::Aborted,
            Err(e) => SessionStatus::Failed { reason: format!("{e:#}") },
        };
    }
}

/// A detachable, cloneable view of a running [`Session`]: poll
/// [`SessionProbe::status`] / [`SessionProbe::is_finished`] and request a
/// cooperative [`SessionProbe::abort`] from another thread while the
/// session handle itself (and its event stream) is owned elsewhere —
/// the seam the `daemon` registry's per-run drain threads hang off.
#[derive(Clone, Debug)]
pub struct SessionProbe {
    status: Arc<StatusCell>,
    cancel: Arc<AtomicBool>,
}

impl SessionProbe {
    /// Non-blocking status snapshot (see [`SessionStatus`]).
    pub fn status(&self) -> SessionStatus {
        self.status.get()
    }

    /// True once the executor returned (success, abort, or failure).
    pub fn is_finished(&self) -> bool {
        self.status.get().is_terminal()
    }

    /// Same cooperative cancellation as [`Session::abort`].
    pub fn abort(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// A running SparrowRL training session.
///
/// Threading model: one background thread runs the trainer hub (and, in
/// pipelined mode, spawns the scoped actor-worker threads beneath it —
/// they can never outlive the hub). Events flow hub → handle over an
/// unbounded channel, so the runtime never blocks on a slow subscriber.
/// Dropping an unjoined `Session` aborts the run and joins the thread —
/// a session cannot leak a running loop.
pub struct Session {
    rx: Receiver<Event>,
    cancel: Arc<AtomicBool>,
    status: Arc<StatusCell>,
    thread: Option<JoinHandle<Result<super::events::RunTail>>>,
    asm: Option<ReportAssembler>,
    finished: Option<RunReport>,
    error: Option<anyhow::Error>,
}

impl Session {
    /// Start a run on the plan's PJRT artifacts (`make artifacts`).
    /// Synthetic plans have no artifacts — pair them with
    /// [`Session::start_with_compute`].
    pub fn start(plan: &RunPlan) -> Result<Session> {
        if plan.synthetic {
            bail!("a synthetic RunSpec has no artifacts; use Session::start_with_compute");
        }
        let spec = crate::config::model(&plan.cfg.model)
            .with_context(|| format!("unknown model {}", plan.cfg.model))?;
        let eng = Engines::load(&crate::runtime::artifacts_dir(), &plan.cfg.model)?;
        Session::spawn(plan.cfg.clone(), spec.layout.clone(), eng, plan.mode)
    }

    /// Start a run on a caller-supplied compute backend (synthetic or
    /// otherwise); `layout` must match the backend's parameter geometry.
    pub fn start_with_compute<C: Compute + Send + 'static>(
        plan: &RunPlan,
        layout: ModelLayout,
        comp: C,
    ) -> Result<Session> {
        Session::spawn(plan.cfg.clone(), layout, comp, plan.mode)
    }

    /// The engine under both `start` flavors and the deprecated
    /// `rt::run_local_mode` shim.
    pub(crate) fn spawn<C: Compute + Send + 'static>(
        cfg: LocalRunConfig,
        layout: ModelLayout,
        comp: C,
        mode: ExecMode,
    ) -> Result<Session> {
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel_flag = cancel.clone();
        let status = Arc::new(StatusCell::new());
        let status_cell = status.clone();
        let thread = std::thread::Builder::new()
            .name("sparrowrl-session".to_string())
            .spawn(move || {
                let mut sink = |ev: Event| {
                    status_cell.observe(&ev);
                    // A dropped handle only means nobody is listening;
                    // the run itself is cancelled via the abort flag.
                    let _ = tx.send(ev);
                };
                let result = run_observed(&cfg, &layout, &comp, mode, &mut sink, &cancel_flag);
                status_cell.finish(&result);
                result
            })
            .map_err(|e| anyhow!("spawn session thread: {e}"))?;
        Ok(Session {
            rx,
            cancel,
            status,
            thread: Some(thread),
            asm: Some(ReportAssembler::default()),
            finished: None,
            error: None,
        })
    }

    /// Non-blocking status snapshot: live progress while the executor
    /// runs, the terminal state the instant it returns — without
    /// consuming the event stream or blocking on [`Session::join`].
    pub fn status(&self) -> SessionStatus {
        self.status.get()
    }

    /// True once the executor returned (success, abort, or failure); the
    /// registry-style poll that replaces watching for `Event::Finished`.
    pub fn is_finished(&self) -> bool {
        self.status.get().is_terminal()
    }

    /// A cloneable probe (status + abort) that outlives handing the
    /// session itself to another thread.
    pub fn probe(&self) -> SessionProbe {
        SessionProbe { status: self.status.clone(), cancel: self.cancel.clone() }
    }

    /// Blocking: the next event, or `None` once the stream is exhausted
    /// (after [`Event::Finished`] on success; immediately on failure —
    /// the error then comes out of [`Session::join`]).
    pub fn recv(&mut self) -> Option<Event> {
        if self.finished.is_some() {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                if let Some(asm) = self.asm.as_mut() {
                    asm.record(&ev);
                }
                Some(ev)
            }
            Err(_) => self.finish_event(),
        }
    }

    /// Non-blocking: the next event if one is ready.
    pub fn try_recv(&mut self) -> Option<Event> {
        if self.finished.is_some() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                if let Some(asm) = self.asm.as_mut() {
                    asm.record(&ev);
                }
                Some(ev)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => self.finish_event(),
        }
    }

    /// Non-blocking drain of everything currently available.
    pub fn try_iter(&mut self) -> impl Iterator<Item = Event> + '_ {
        std::iter::from_fn(move || self.try_recv())
    }

    /// Ask the run to stop at its next cancellation point (step
    /// boundaries and the collect loop's poll ticks). Cooperative and
    /// idempotent; `join()` then returns the abort error.
    pub fn abort(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Wait for the run to finish and return its report (assembled from
    /// the event stream). Consumes the session; events not yet consumed
    /// are drained (and folded into the report) on the way.
    pub fn join(mut self) -> Result<RunReport> {
        while self.recv().is_some() {}
        if let Some(report) = self.finished.take() {
            return Ok(report);
        }
        Err(self
            .error
            .take()
            .unwrap_or_else(|| anyhow!("session ended without a result")))
    }

    /// The channel closed: the runtime returned. Join the thread and
    /// either synthesize the terminal [`Event::Finished`] (success) or
    /// record the error for [`Session::join`].
    fn finish_event(&mut self) -> Option<Event> {
        let handle = self.thread.take()?;
        match handle.join() {
            Ok(Ok(tail)) => {
                let report = self.asm.take()?.finish(tail);
                self.finished = Some(report.clone());
                Some(Event::Finished(report))
            }
            Ok(Err(e)) => {
                self.error = Some(e);
                None
            }
            Err(_) => {
                self.error = Some(anyhow!("session thread panicked"));
                None
            }
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if let Some(handle) = self.thread.take() {
            self.cancel.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
    }
}
