//! [`RunSpec`]: the validated run-specification builder.
//!
//! A `RunSpec` owns *all* run configuration — model, algorithm, executor
//! mode, transport backend, WAN distribution, lease policy, determinism —
//! and its [`RunSpec::build`] performs every cross-field legality check
//! in one place, returning typed [`SpecError`]s for illegal combinations
//! and typed [`SpecNote`]s for the auto-coercions that used to happen
//! silently inside the CLI (wan → pipelined, wan → actor count, wan →
//! relay tree). A successful build yields a [`RunPlan`]: the frozen,
//! internally-consistent configuration a [`Session`](super::Session)
//! starts from.

use crate::config;
use crate::data::Benchmark;
use crate::ledger::LeasePolicy;
use crate::netsim::Link;
use crate::rt::{
    BootstrapKind, DistributionSpec, ElasticSpec, ExecMode, JoinSpec, LeaveSpec, LocalRunConfig,
    SwapSpec, TransportKind,
};
use crate::trainer::Algorithm;
use crate::transport::{DistributionPlan, SimNetConfig, TcpConfig};
use std::fmt;

/// Transport backend selection for a [`RunSpec`]. `Sim` synthesizes its
/// WAN topology at build time (from the WAN preset when one is set, a
/// single emulated Canada leg otherwise); `SimNet` supplies an explicit
/// topology; `Tcp` runs real loopback sockets.
#[derive(Clone, Debug, Default)]
pub enum Backend {
    /// In-process mailboxes (zero-copy; relay-routed under a WAN preset).
    #[default]
    InProc,
    /// Netsim WAN model, topology derived at `build()`.
    Sim,
    /// Netsim WAN model over an explicit topology.
    SimNet(SimNetConfig),
    /// Real loopback sockets: framed, striped, optionally throttled.
    Tcp(TcpConfig),
}

impl Backend {
    /// The names `sparrowrl list` advertises and `--transport` accepts.
    pub const NAMES: [&'static str; 3] = ["inproc", "sim", "tcp"];

    /// Parse a CLI-style backend name (`tcp` gets the default config;
    /// refine with [`Backend::Tcp`] directly for streams/throttle/kill).
    pub fn parse(name: &str) -> Option<Backend> {
        match name {
            "inproc" => Some(Backend::InProc),
            "sim" => Some(Backend::Sim),
            "tcp" => Some(Backend::Tcp(TcpConfig::default())),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::InProc => "inproc",
            Backend::Sim | Backend::SimNet(_) => "sim",
            Backend::Tcp(_) => "tcp",
        }
    }
}

/// A combination of [`RunSpec`] fields that cannot run. Every variant
/// corresponds to one legality rule that used to live as a `bail!` in
/// `main.rs::cmd_train` or deep inside the runtime; `build()` rejects
/// them all up front with an actionable message.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// The model name matches no preset (`config::model`).
    UnknownModel(String),
    /// The model exists but is analytic-only (simulator sizing, never
    /// compiled); pick a `sparrow-*` model or a synthetic spec.
    AnalyticOnlyModel(String),
    /// `wan(..)` named no `wan-1..wan-4` preset.
    UnknownWanPreset(String),
    /// A WAN preset fixes the fleet size; an explicit `actors(..)` call
    /// conflicts with it.
    ActorsConflictWithWan { preset: String, actors: usize },
    /// `sequential()` was requested together with a feature that only the
    /// pipelined executor implements.
    SequentialConflict { feature: &'static str },
    /// The Tcp backend streams hub→actor directly; WAN relay trees need
    /// the sim backend.
    TcpConflictsWithWan,
    /// The Tcp backend cannot route an in-process relay tree.
    TcpConflictsWithDistribution,
    /// The sim backend owns its own relay tree; an explicit in-process
    /// `distribution(..)` would be dead wiring.
    SimConflictsWithDistribution,
    /// An explicit `SimNet` topology and a WAN preset both describe the
    /// fleet; pick one.
    SimNetConflictsWithWan,
    /// The explicit `SimNet` topology covers a different number of actors
    /// than the spec runs.
    SimTopologyMismatch { covers: usize, actors: usize },
    /// The in-process `distribution(..)` covers a different number of
    /// actors than the spec runs.
    DistributionMismatch { covers: usize, actors: usize },
    /// `distribution(..)` and `wan(..)` both describe a relay tree.
    DistributionConflictsWithWan,
    /// Scripted joins/leaves need a backend whose fleet can change at
    /// runtime; the netsim fleet is fixed at topology-build time.
    ElasticConflictsWithSim,
    /// Elastic membership streams hub→actor directly; relay trees (WAN
    /// presets or explicit non-flat distributions) cannot rewire live.
    ElasticConflictsWithRelayTree,
    /// Scripted joiners must extend the day-one fleet contiguously: with
    /// `actors(n)` and `j` joins, the joiner ids must be exactly
    /// `n..n+j`, one each.
    ElasticJoinerIds { actors: usize, joins: usize },
    /// A scripted membership change is pinned to a version the run never
    /// commits (valid pins are `1..=steps`), or names an unknown actor.
    ElasticVersionOutOfRange { actor: u32, version: u64, steps: u64 },
    ZeroActors,
    ZeroGroupSize,
    ZeroSegmentBytes,
    /// `LeasePolicy::sweep_ms` is the collect-loop poll interval; zero
    /// would spin the hub thread.
    ZeroSweepInterval,
    /// `resume()` recovers from a durable store; without `persist_dir(..)`
    /// there is nothing to recover from.
    ResumeNeedsPersistDir,
    /// Resume replays the crash-lost in-flight batch; only the
    /// deterministic schedule (without wall-clock leases) makes the
    /// replay bit-exact.
    ResumeRequiresDeterministic,
    /// A resumed run cannot re-run a membership script relative to a
    /// recovered version history.
    ResumeConflictsWithElastic,
    /// `publish_to(..)` folds the durable journal; without
    /// `persist_dir(..)` there is nothing to publish.
    PublishNeedsPersistDir,
    /// `swap_to(..)` reads published fine-tunes; it needs `registry(..)`
    /// (or `publish_to(..)`, which sets the registry too).
    SwapNeedsRegistry,
    /// A scripted swap names an actor outside the day-one fleet.
    SwapActorOutOfRange { actor: u32, n_actors: usize },
    /// Two scripted swaps target the same actor; an epilogue swap is
    /// at most one retarget per actor.
    DuplicateSwapActor { actor: u32 },
}

impl SpecError {
    /// The variant's stable name (`"ZeroActors"`, `"TcpConflictsWithWan"`,
    /// ...) — the machine-readable tag the daemon's 422 bodies carry so
    /// remote submitters can match on the typed error, not its prose.
    pub fn name(&self) -> &'static str {
        match self {
            SpecError::UnknownModel(_) => "UnknownModel",
            SpecError::AnalyticOnlyModel(_) => "AnalyticOnlyModel",
            SpecError::UnknownWanPreset(_) => "UnknownWanPreset",
            SpecError::ActorsConflictWithWan { .. } => "ActorsConflictWithWan",
            SpecError::SequentialConflict { .. } => "SequentialConflict",
            SpecError::TcpConflictsWithWan => "TcpConflictsWithWan",
            SpecError::TcpConflictsWithDistribution => "TcpConflictsWithDistribution",
            SpecError::SimConflictsWithDistribution => "SimConflictsWithDistribution",
            SpecError::SimNetConflictsWithWan => "SimNetConflictsWithWan",
            SpecError::SimTopologyMismatch { .. } => "SimTopologyMismatch",
            SpecError::DistributionMismatch { .. } => "DistributionMismatch",
            SpecError::DistributionConflictsWithWan => "DistributionConflictsWithWan",
            SpecError::ElasticConflictsWithSim => "ElasticConflictsWithSim",
            SpecError::ElasticConflictsWithRelayTree => "ElasticConflictsWithRelayTree",
            SpecError::ElasticJoinerIds { .. } => "ElasticJoinerIds",
            SpecError::ElasticVersionOutOfRange { .. } => "ElasticVersionOutOfRange",
            SpecError::ZeroActors => "ZeroActors",
            SpecError::ZeroGroupSize => "ZeroGroupSize",
            SpecError::ZeroSegmentBytes => "ZeroSegmentBytes",
            SpecError::ZeroSweepInterval => "ZeroSweepInterval",
            SpecError::ResumeNeedsPersistDir => "ResumeNeedsPersistDir",
            SpecError::ResumeRequiresDeterministic => "ResumeRequiresDeterministic",
            SpecError::ResumeConflictsWithElastic => "ResumeConflictsWithElastic",
            SpecError::PublishNeedsPersistDir => "PublishNeedsPersistDir",
            SpecError::SwapNeedsRegistry => "SwapNeedsRegistry",
            SpecError::SwapActorOutOfRange { .. } => "SwapActorOutOfRange",
            SpecError::DuplicateSwapActor { .. } => "DuplicateSwapActor",
        }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::UnknownModel(m) => write!(f, "unknown model {m:?} (see `sparrowrl list`)"),
            SpecError::AnalyticOnlyModel(m) => {
                write!(f, "{m} is analytic-only; pick a sparrow-* model or RunSpec::synthetic()")
            }
            SpecError::UnknownWanPreset(w) => write!(f, "unknown WAN preset {w} (wan-1..wan-4)"),
            SpecError::ActorsConflictWithWan { preset, actors } => write!(
                f,
                "{preset} sets the actor count from the preset; drop the explicit actors({actors})"
            ),
            SpecError::SequentialConflict { feature } => write!(
                f,
                "the sequential reference executor does not support {feature}; drop sequential() \
                 or the conflicting option"
            ),
            SpecError::TcpConflictsWithWan => write!(
                f,
                "the tcp backend streams hub→actor directly; combine wan(..) with the sim backend"
            ),
            SpecError::TcpConflictsWithDistribution => write!(
                f,
                "the tcp backend cannot route an in-process relay tree; use inproc or sim"
            ),
            SpecError::SimConflictsWithDistribution => write!(
                f,
                "the sim backend owns the relay tree; drop the explicit distribution(..)"
            ),
            SpecError::SimNetConflictsWithWan => write!(
                f,
                "an explicit SimNet topology and a wan(..) preset both describe the fleet; pick one"
            ),
            SpecError::SimTopologyMismatch { covers, actors } => write!(
                f,
                "sim transport topology covers {covers} actors but the spec runs {actors}"
            ),
            SpecError::DistributionMismatch { covers, actors } => write!(
                f,
                "distribution spec covers {covers} actors but the spec runs {actors}"
            ),
            SpecError::DistributionConflictsWithWan => write!(
                f,
                "wan(..) derives the relay tree itself; drop the explicit distribution(..)"
            ),
            SpecError::ElasticConflictsWithSim => write!(
                f,
                "scripted joins/leaves need the inproc or tcp backend (the netsim fleet is fixed)"
            ),
            SpecError::ElasticConflictsWithRelayTree => write!(
                f,
                "elastic membership streams hub→actor directly; drop wan(..)/distribution(..)"
            ),
            SpecError::ElasticJoinerIds { actors, joins } => write!(
                f,
                "scripted joiners must be actors {actors}..{} exactly (one id each)",
                actors + joins
            ),
            SpecError::ElasticVersionOutOfRange { actor, version, steps } => write!(
                f,
                "membership change for actor {actor} pinned at v{version} outside 1..={steps} \
                 (or the actor id is outside the fleet)"
            ),
            SpecError::ZeroActors => write!(f, "need at least one actor"),
            SpecError::ZeroGroupSize => write!(f, "group_size must be at least 1"),
            SpecError::ZeroSegmentBytes => write!(f, "segment_bytes must be at least 1"),
            SpecError::ZeroSweepInterval => {
                write!(f, "lease sweep_ms must be at least 1 (it paces the hub's poll loop)")
            }
            SpecError::ResumeNeedsPersistDir => {
                write!(f, "resume() needs persist_dir(..) to name the durable store to recover")
            }
            SpecError::ResumeRequiresDeterministic => write!(
                f,
                "resume() requires deterministic() without wall_leases() — the crash-lost \
                 batch is replayed bit-exactly under the deterministic schedule"
            ),
            SpecError::ResumeConflictsWithElastic => write!(
                f,
                "resume() cannot be combined with join_at(..)/leave_at(..); restart the \
                 membership script in a fresh run instead"
            ),
            SpecError::PublishNeedsPersistDir => write!(
                f,
                "publish_to(..) folds the durable journal; add persist_dir(..) so there is \
                 a chain to publish"
            ),
            SpecError::SwapNeedsRegistry => write!(
                f,
                "swap_to(..) reads published fine-tunes; add registry(..) to name the model \
                 registry"
            ),
            SpecError::SwapActorOutOfRange { actor, n_actors } => write!(
                f,
                "swap_to(..) names actor {actor} but the fleet runs actors 0..{n_actors}"
            ),
            SpecError::DuplicateSwapActor { actor } => write!(
                f,
                "actor {actor} is named by more than one swap_to(..); an epilogue swap is at \
                 most one retarget per actor"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// A legal auto-coercion `build()` performed. These used to be silent (or
/// `println!`ed) inside the CLI; a typed note lets any caller surface
/// them however it likes.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecNote {
    /// A feature that only the pipelined executor implements was selected
    /// without an explicit mode, so the plan runs pipelined.
    PipelinedCoerced { cause: &'static str },
    /// The WAN preset fixed the fleet size.
    WanSetsActorCount { preset: String, actors: usize },
    /// The WAN preset became an in-process relay tree (InProc backend):
    /// the hub streams each segment once per region, relays forward.
    WanRelayTree { preset: String, regions: usize, relays: Vec<usize> },
}

impl fmt::Display for SpecNote {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecNote::PipelinedCoerced { cause } => {
                write!(f, "{cause} implies the pipelined executor")
            }
            SpecNote::WanSetsActorCount { preset, actors } => {
                write!(f, "{preset} sets the fleet to {actors} actors")
            }
            SpecNote::WanRelayTree { preset, regions, relays } => {
                write!(f, "{preset}: {regions} region(s) as an in-process relay tree, relays {relays:?}")
            }
        }
    }
}

/// Builder for a validated run. Construct with [`RunSpec::model`] (a
/// runnable `sparrow-*` preset, executed through PJRT artifacts) or
/// [`RunSpec::synthetic`] (artifact-free, paired with a caller-supplied
/// compute backend at start), chain setters, then [`RunSpec::build`].
#[derive(Clone, Debug)]
pub struct RunSpec {
    model: String,
    synthetic: bool,
    algorithm: Algorithm,
    bench: Benchmark,
    actors: Option<usize>,
    group_size: usize,
    steps: u64,
    sft_steps: u64,
    lr_sft: f32,
    lr_rl: f32,
    max_new_tokens: usize,
    temperature: f32,
    segment_bytes: usize,
    seed: u64,
    verbose: bool,
    deterministic: bool,
    wall_leases: bool,
    lease: LeasePolicy,
    mode: Option<ExecMode>,
    wan: Option<String>,
    backend: Backend,
    distribution: Option<DistributionSpec>,
    elastic: ElasticSpec,
    persist_dir: Option<std::path::PathBuf>,
    resume: bool,
    registry_dir: Option<std::path::PathBuf>,
    swaps: Vec<SwapSpec>,
    publish: Option<String>,
}

impl RunSpec {
    fn defaults(model: &str, synthetic: bool) -> RunSpec {
        RunSpec {
            model: model.to_string(),
            synthetic,
            algorithm: Algorithm::Grpo,
            bench: Benchmark::Gsm8k,
            actors: None,
            group_size: 4,
            steps: 5,
            sft_steps: 30,
            lr_sft: 5e-3,
            lr_rl: 1e-6,
            max_new_tokens: 8,
            temperature: 0.8,
            segment_bytes: 16 << 10,
            seed: 0,
            verbose: false,
            deterministic: false,
            wall_leases: false,
            lease: LeasePolicy::default(),
            mode: None,
            wan: None,
            backend: Backend::InProc,
            distribution: None,
            elastic: ElasticSpec::default(),
            persist_dir: None,
            resume: false,
            registry_dir: None,
            swaps: Vec::new(),
            publish: None,
        }
    }

    /// Spec for a runnable model preset (validated at `build()`).
    pub fn model(name: &str) -> RunSpec {
        RunSpec::defaults(name, false)
    }

    /// Spec for an artifact-free run on a caller-supplied [`Compute`]
    /// backend (`Session::start_with_compute`); skips the model lookup.
    ///
    /// [`Compute`]: crate::rt::Compute
    pub fn synthetic() -> RunSpec {
        RunSpec::defaults("synthetic", true)
    }

    pub fn algorithm(mut self, a: Algorithm) -> RunSpec {
        self.algorithm = a;
        self
    }

    pub fn bench(mut self, b: Benchmark) -> RunSpec {
        self.bench = b;
        self
    }

    /// Fleet size. Conflicts with [`RunSpec::wan`], which derives it.
    pub fn actors(mut self, n: usize) -> RunSpec {
        self.actors = Some(n);
        self
    }

    /// Rollout group size per prompt (GRPO's G).
    pub fn group_size(mut self, g: usize) -> RunSpec {
        self.group_size = g;
        self
    }

    /// RL steps to run.
    pub fn steps(mut self, s: u64) -> RunSpec {
        self.steps = s;
        self
    }

    /// Supervised warmup steps before RL.
    pub fn sft_steps(mut self, s: u64) -> RunSpec {
        self.sft_steps = s;
        self
    }

    pub fn lr_sft(mut self, lr: f32) -> RunSpec {
        self.lr_sft = lr;
        self
    }

    pub fn lr_rl(mut self, lr: f32) -> RunSpec {
        self.lr_rl = lr;
        self
    }

    pub fn max_new_tokens(mut self, n: usize) -> RunSpec {
        self.max_new_tokens = n;
        self
    }

    pub fn temperature(mut self, t: f32) -> RunSpec {
        self.temperature = t;
        self
    }

    /// Delta wire-segment size (smaller = more mid-generation staging).
    pub fn segment_bytes(mut self, b: usize) -> RunSpec {
        self.segment_bytes = b;
        self
    }

    pub fn seed(mut self, s: u64) -> RunSpec {
        self.seed = s;
        self
    }

    /// Print per-step progress lines from inside the runtime (the event
    /// stream is the richer interface; this mirrors the legacy knob).
    pub fn verbose(mut self) -> RunSpec {
        self.verbose = true;
        self
    }

    /// Deterministic virtual time: a seed fully determines the run and
    /// all executors/backends commit bit-identical policies.
    pub fn deterministic(mut self) -> RunSpec {
        self.deterministic = true;
        self
    }

    /// Keep wall-clock leases even under `deterministic` (stalls still
    /// time out; the fault-tolerance configuration).
    pub fn wall_leases(mut self) -> RunSpec {
        self.wall_leases = true;
        self
    }

    /// Job-ledger lease policy override.
    pub fn lease(mut self, p: LeasePolicy) -> RunSpec {
        self.lease = p;
        self
    }

    /// Overlapped one-step async executor.
    pub fn pipelined(self) -> RunSpec {
        self.mode(ExecMode::Pipelined)
    }

    /// Phase-sequential reference executor (rejects pipelined-only
    /// features at `build()` instead of silently coercing).
    pub fn sequential(self) -> RunSpec {
        self.mode(ExecMode::Sequential)
    }

    /// Explicit executor choice (programmatic form of
    /// [`pipelined`](RunSpec::pipelined)/[`sequential`](RunSpec::sequential)).
    pub fn mode(mut self, m: ExecMode) -> RunSpec {
        self.mode = Some(m);
        self
    }

    /// Multi-region WAN preset (`wan-1`..`wan-4`): derives the fleet
    /// size, the relay tree (InProc) or netsim topology (Sim), and
    /// implies the pipelined executor.
    pub fn wan(mut self, preset: &str) -> RunSpec {
        self.wan = Some(preset.to_string());
        self
    }

    /// Transport backend (see [`Backend`]).
    pub fn transport(mut self, b: Backend) -> RunSpec {
        self.backend = b;
        self
    }

    /// Explicit in-process relay-tree wiring (tests / custom topologies;
    /// [`RunSpec::wan`] derives this automatically).
    pub fn distribution(mut self, d: DistributionSpec) -> RunSpec {
        self.distribution = Some(d);
        self
    }

    /// Script a live join: `actor` (which must extend the day-one fleet
    /// contiguously — actor ids `n..n+joins`) is invited once the trainer
    /// commits `version`, bootstraps via `bootstrap`, and enters the
    /// scheduler after its SHA-256 policy witness verifies.
    pub fn join_at(mut self, actor: u32, version: u64, bootstrap: BootstrapKind) -> RunSpec {
        self.elastic.joins.push(JoinSpec { actor, at_version: version, bootstrap });
        self
    }

    /// Script a graceful leave: once the trainer commits `version` the
    /// hub stops scheduling `actor`, lets its in-flight leases settle,
    /// and releases it with a drain handshake (counted in
    /// `RunReport::drains`, never `failovers`).
    pub fn leave_at(mut self, actor: u32, version: u64) -> RunSpec {
        self.elastic.leaves.push(LeaveSpec { actor, at_version: version });
        self
    }

    /// Evaluate the cost-model autoscaler each step and emit typed
    /// `Event::Autoscale` decisions (advisory; the fleet only follows
    /// the explicit join/leave script).
    pub fn autoscale(mut self) -> RunSpec {
        self.elastic.autoscale = true;
        self
    }

    /// Collect-loop poll / lease-expiry sweep interval override
    /// (milliseconds; shorthand for setting `LeasePolicy::sweep_ms`
    /// through [`RunSpec::lease`]).
    pub fn lease_sweep_ms(mut self, ms: u64) -> RunSpec {
        self.lease.sweep_ms = ms;
        self
    }

    /// Make the run durable: every committed version seals its delta
    /// artifact, full optimizer state, and an append-only journal record
    /// under `dir` (a content-addressed store,
    /// [`crate::delta::DurableStore`]) *before* the version becomes
    /// observable. A crash at any point — including between the object
    /// seal and the journal append — leaves a store that
    /// [`RunSpec::resume`] continues bit-exactly.
    pub fn persist_dir(mut self, dir: impl Into<std::path::PathBuf>) -> RunSpec {
        self.persist_dir = Some(dir.into());
        self
    }

    /// Continue the durable run under [`RunSpec::persist_dir`] from its
    /// last journaled version: the optimizer state is restored, RNG
    /// streams re-seeded from the journal, and the crash-lost in-flight
    /// batch regenerated, so the resumed committed-checksum trace is
    /// bitwise identical to an uninterrupted run's. Requires
    /// [`RunSpec::deterministic`] and no elastic script.
    pub fn resume(mut self) -> RunSpec {
        self.resume = true;
        self
    }

    /// Name the [`crate::delta::ModelRegistry`] directory this run reads
    /// published fine-tunes from (required by [`RunSpec::swap_to`];
    /// implied by [`RunSpec::publish_to`]).
    pub fn registry(mut self, dir: impl Into<std::path::PathBuf>) -> RunSpec {
        self.registry_dir = Some(dir.into());
        self
    }

    /// Publish the finished run into the registry at `dir` under model
    /// `name`: the durable chain is folded through `merge_chain`,
    /// verified against the journaled witness, and stored
    /// content-addressed off the run's base object — so N runs sharing a
    /// base store that base exactly once. Requires
    /// [`RunSpec::persist_dir`].
    pub fn publish_to(mut self, dir: impl Into<std::path::PathBuf>, name: &str) -> RunSpec {
        self.registry_dir = Some(dir.into());
        self.publish = Some(name.to_string());
        self
    }

    /// Script an epilogue hot-swap: after the final training commit,
    /// retarget `actor` onto the published fine-tune `model@version` by
    /// shipping only the composed registry swap delta (bit-exact —
    /// the actor's post-swap checksum must equal the registry's
    /// published witness). Requires [`RunSpec::registry`]; at most one
    /// swap per actor.
    pub fn swap_to(mut self, actor: u32, model: &str, version: u64) -> RunSpec {
        self.swaps.push(SwapSpec { actor, model: model.to_string(), version });
        self
    }

    /// Validate every cross-field rule and freeze the configuration.
    /// Illegal combinations return a typed [`SpecError`]; legal
    /// auto-coercions are recorded as [`SpecNote`]s on the plan.
    pub fn build(self) -> Result<RunPlan, SpecError> {
        let mut notes = Vec::new();

        // -- model ---------------------------------------------------------
        if !self.synthetic {
            match config::model(&self.model) {
                None => return Err(SpecError::UnknownModel(self.model.clone())),
                Some(spec) if !spec.runnable => {
                    return Err(SpecError::AnalyticOnlyModel(self.model.clone()))
                }
                Some(_) => {}
            }
        }
        if self.group_size == 0 {
            return Err(SpecError::ZeroGroupSize);
        }
        if self.segment_bytes == 0 {
            return Err(SpecError::ZeroSegmentBytes);
        }
        if self.lease.sweep_ms == 0 {
            return Err(SpecError::ZeroSweepInterval);
        }

        // -- durability / resume ------------------------------------------
        if self.resume {
            if self.persist_dir.is_none() {
                return Err(SpecError::ResumeNeedsPersistDir);
            }
            if !self.deterministic || self.wall_leases {
                return Err(SpecError::ResumeRequiresDeterministic);
            }
            if !self.elastic.joins.is_empty() || !self.elastic.leaves.is_empty() {
                return Err(SpecError::ResumeConflictsWithElastic);
            }
        }

        // -- registry: publish / hot-swaps --------------------------------
        if self.publish.is_some() && self.persist_dir.is_none() {
            return Err(SpecError::PublishNeedsPersistDir);
        }
        if !self.swaps.is_empty() && self.registry_dir.is_none() {
            return Err(SpecError::SwapNeedsRegistry);
        }

        // -- WAN preset → fleet size --------------------------------------
        let preset = match &self.wan {
            Some(name) => Some(
                config::wan_preset(name)
                    .ok_or_else(|| SpecError::UnknownWanPreset(name.clone()))?,
            ),
            None => None,
        };
        if let (Some(p), Some(n)) = (&preset, self.actors) {
            return Err(SpecError::ActorsConflictWithWan {
                preset: p.name.to_string(),
                actors: n,
            });
        }
        let n_actors = match (&preset, self.actors) {
            (Some(p), _) => {
                notes.push(SpecNote::WanSetsActorCount {
                    preset: p.name.to_string(),
                    actors: p.n_actors(),
                });
                p.n_actors()
            }
            (None, Some(n)) => n,
            (None, None) => 2,
        };
        if n_actors == 0 {
            return Err(SpecError::ZeroActors);
        }

        // -- executor mode: explicit wins, features coerce ----------------
        let needs_pipeline: Option<&'static str> = if preset.is_some() {
            Some("a WAN preset")
        } else if !self.elastic.is_empty() {
            Some("elastic membership")
        } else {
            match &self.backend {
                Backend::Sim | Backend::SimNet(_) => Some("the sim transport"),
                Backend::Tcp(_) => Some("the tcp transport"),
                Backend::InProc => None,
            }
        };
        let mode = match (self.mode, needs_pipeline) {
            (Some(ExecMode::Sequential), Some(feature)) => {
                return Err(SpecError::SequentialConflict { feature })
            }
            (Some(m), _) => m,
            (None, Some(cause)) => {
                notes.push(SpecNote::PipelinedCoerced { cause });
                ExecMode::Pipelined
            }
            (None, None) => ExecMode::Sequential,
        };

        // -- distribution tree --------------------------------------------
        let mut distribution = self.distribution;
        if let Some(spec) = &distribution {
            if preset.is_some() {
                return Err(SpecError::DistributionConflictsWithWan);
            }
            if !spec.is_flat() && spec.region_of.len() != n_actors {
                return Err(SpecError::DistributionMismatch {
                    covers: spec.region_of.len(),
                    actors: n_actors,
                });
            }
        }

        // -- transport backend --------------------------------------------
        let transport = match self.backend {
            Backend::InProc => {
                if let Some(p) = &preset {
                    let plan = DistributionPlan::from_preset(p, 1 << 20);
                    notes.push(SpecNote::WanRelayTree {
                        preset: p.name.to_string(),
                        regions: p.regions.len(),
                        relays: plan.legs.iter().map(|l| l.relay).collect(),
                    });
                    distribution = Some(DistributionSpec::from_plan(&plan));
                }
                TransportKind::InProc
            }
            Backend::Sim => {
                if distribution.is_some() {
                    return Err(SpecError::SimConflictsWithDistribution);
                }
                let net = match &preset {
                    Some(p) => SimNetConfig::from_preset(p, self.seed),
                    None => SimNetConfig::single_region(
                        n_actors,
                        Link::from_profile(&config::regions::CANADA),
                        4,
                        self.seed,
                    ),
                };
                TransportKind::Sim(net)
            }
            Backend::SimNet(net) => {
                if preset.is_some() {
                    return Err(SpecError::SimNetConflictsWithWan);
                }
                if distribution.is_some() {
                    return Err(SpecError::SimConflictsWithDistribution);
                }
                if net.region_of.len() != n_actors {
                    return Err(SpecError::SimTopologyMismatch {
                        covers: net.region_of.len(),
                        actors: n_actors,
                    });
                }
                TransportKind::Sim(net)
            }
            Backend::Tcp(tc) => {
                if preset.is_some() {
                    return Err(SpecError::TcpConflictsWithWan);
                }
                if distribution.as_ref().map_or(false, |d| !d.is_flat()) {
                    return Err(SpecError::TcpConflictsWithDistribution);
                }
                TransportKind::Tcp(tc)
            }
        };

        // -- elastic membership -------------------------------------------
        if !self.elastic.joins.is_empty() || !self.elastic.leaves.is_empty() {
            if matches!(transport, TransportKind::Sim(_)) {
                return Err(SpecError::ElasticConflictsWithSim);
            }
            if preset.is_some() || distribution.as_ref().map_or(false, |d| !d.is_flat()) {
                return Err(SpecError::ElasticConflictsWithRelayTree);
            }
            let n_total = n_actors + self.elastic.joins.len();
            let mut ids: Vec<u32> = self.elastic.joins.iter().map(|j| j.actor).collect();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != self.elastic.joins.len()
                || ids != (n_actors as u32..n_total as u32).collect::<Vec<u32>>()
            {
                return Err(SpecError::ElasticJoinerIds {
                    actors: n_actors,
                    joins: self.elastic.joins.len(),
                });
            }
            for j in &self.elastic.joins {
                if !(1..=self.steps).contains(&j.at_version) {
                    return Err(SpecError::ElasticVersionOutOfRange {
                        actor: j.actor,
                        version: j.at_version,
                        steps: self.steps,
                    });
                }
            }
            for l in &self.elastic.leaves {
                if (l.actor as usize) >= n_total || !(1..=self.steps).contains(&l.at_version) {
                    return Err(SpecError::ElasticVersionOutOfRange {
                        actor: l.actor,
                        version: l.at_version,
                        steps: self.steps,
                    });
                }
            }
        }

        // Swaps target the day-one fleet (the epilogue runs after any
        // scripted joins, but joiner-targeted swaps would tie the swap
        // script to the membership script's success — keep them apart).
        {
            let mut seen: Vec<u32> = Vec::new();
            for s in &self.swaps {
                if (s.actor as usize) >= n_actors {
                    return Err(SpecError::SwapActorOutOfRange { actor: s.actor, n_actors });
                }
                if seen.contains(&s.actor) {
                    return Err(SpecError::DuplicateSwapActor { actor: s.actor });
                }
                seen.push(s.actor);
            }
        }

        let cfg = LocalRunConfig {
            model: self.model,
            algorithm: self.algorithm,
            bench: self.bench,
            n_actors,
            group_size: self.group_size,
            steps: self.steps,
            sft_steps: self.sft_steps,
            lr_sft: self.lr_sft,
            lr_rl: self.lr_rl,
            max_new_tokens: self.max_new_tokens,
            temperature: self.temperature,
            segment_bytes: self.segment_bytes,
            seed: self.seed,
            verbose: self.verbose,
            deterministic: self.deterministic,
            distribution,
            transport,
            lease: self.lease,
            wall_leases: self.wall_leases,
            elastic: self.elastic,
            persist_dir: self.persist_dir,
            resume: self.resume,
            registry_dir: self.registry_dir,
            swaps: self.swaps,
            publish: self.publish,
        };
        Ok(RunPlan { cfg, mode, notes, synthetic: self.synthetic })
    }
}

/// A frozen, validated run configuration: what [`RunSpec::build`]
/// produces and [`Session::start`](super::Session::start) consumes.
#[derive(Clone, Debug)]
pub struct RunPlan {
    pub(crate) cfg: LocalRunConfig,
    pub(crate) mode: ExecMode,
    notes: Vec<SpecNote>,
    pub(crate) synthetic: bool,
}

impl RunPlan {
    /// The resolved low-level configuration (read-only: the builder is
    /// the only way to construct one through this module).
    pub fn config(&self) -> &LocalRunConfig {
        &self.cfg
    }

    /// The executor the plan runs under (explicit choice or coercion).
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Auto-coercions `build()` performed, for surfacing to users.
    pub fn notes(&self) -> &[SpecNote] {
        &self.notes
    }

    /// Amend a not-yet-started plan with an epilogue hot-swap (the
    /// daemon's `POST /runs/{id}/swap` on a queued run). Applies the
    /// same rules `build()` enforces on [`RunSpec::swap_to`]: the
    /// registry is recorded, the actor must be in the day-one fleet,
    /// and at most one swap may target it.
    pub fn add_swap(
        &mut self,
        registry: &std::path::Path,
        actor: u32,
        model: &str,
        version: u64,
    ) -> Result<(), SpecError> {
        if (actor as usize) >= self.cfg.n_actors {
            return Err(SpecError::SwapActorOutOfRange { actor, n_actors: self.cfg.n_actors });
        }
        if self.cfg.swaps.iter().any(|s| s.actor == actor) {
            return Err(SpecError::DuplicateSwapActor { actor });
        }
        self.cfg.registry_dir = Some(registry.to_path_buf());
        self.cfg.swaps.push(SwapSpec { actor, model: model.to_string(), version });
        Ok(())
    }
}
