//! Cost model: deployment pricing and tokens-per-dollar (paper §7.7,
//! Tables 1 and 6).
//!
//! Reserved RDMA clusters come in fixed 8-GPU blocks at a network premium
//! with minimum commitments; cross-cloud capacity is per-GPU on-demand.
//! Following the paper, tokens/$ uses *amortized* hourly rates (which
//! favours SingleDC for short runs — the comparison is conservative).

use crate::config::GpuClass;

/// One homogeneous block of GPUs in a deployment.
#[derive(Clone, Copy, Debug)]
pub struct GpuPool {
    pub class: GpuClass,
    pub count: usize,
}

/// How the GPUs are procured (drives pricing + connectivity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Procurement {
    /// On-demand cross-cloud VMs, standard networking, 1-hour billing.
    OnDemandCrossCloud,
    /// Reserved RDMA cluster, 8-GPU blocks, minimum commitment.
    ReservedRdma,
}

/// A full deployment description.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub name: String,
    pub pools: Vec<GpuPool>,
    pub procurement: Procurement,
}

impl Deployment {
    pub fn cross_cloud(name: &str, pools: Vec<GpuPool>) -> Deployment {
        Deployment { name: name.into(), pools, procurement: Procurement::OnDemandCrossCloud }
    }

    /// Reserved RDMA cluster: `count` is rounded UP to 8-GPU blocks
    /// (Table 6: "must round up to 2x8xH100").
    pub fn reserved_rdma(name: &str, class: GpuClass, count: usize) -> Deployment {
        let rounded = count.div_ceil(8) * 8;
        Deployment {
            name: name.into(),
            pools: vec![GpuPool { class, count: rounded }],
            procurement: Procurement::ReservedRdma,
        }
    }

    pub fn gpu_count(&self) -> usize {
        self.pools.iter().map(|p| p.count).sum()
    }

    /// Hourly cost in dollars.
    pub fn cost_per_hr(&self) -> f64 {
        self.pools
            .iter()
            .map(|p| {
                let rate = match self.procurement {
                    Procurement::OnDemandCrossCloud => p.class.on_demand_per_hr(),
                    Procurement::ReservedRdma => p.class.reserved_rdma_per_hr(),
                };
                rate * p.count as f64
            })
            .sum()
    }

    /// Tokens per dollar given sustained throughput (tokens/s).
    pub fn tokens_per_dollar(&self, tokens_per_s: f64) -> f64 {
        tokens_per_s * 3600.0 / self.cost_per_hr()
    }

    /// Total cost of a run, honouring minimum commitments (Table 1:
    /// reserved clusters bill at least `min_commit_hr` hours).
    pub fn run_cost(&self, run_hours: f64) -> f64 {
        let billed = match self.procurement {
            Procurement::OnDemandCrossCloud => run_hours.max(1.0), // 1-hr billing
            Procurement::ReservedRdma => run_hours.max(24.0),      // 24-hr min commit
        };
        billed * self.cost_per_hr()
    }

    /// Cross-region egress cost of moving `bytes` out of the trainer's
    /// cloud. Reserved RDMA deployments keep all traffic in-fabric (free);
    /// cross-cloud deployments pay commodity egress per GB — the term the
    /// 79x payload reduction shrinks along with transfer time.
    pub fn egress_cost(&self, bytes: u64) -> f64 {
        match self.procurement {
            Procurement::OnDemandCrossCloud => bytes as f64 / 1e9 * EGRESS_PER_GB,
            Procurement::ReservedRdma => 0.0,
        }
    }

    /// Tokens per dollar including delta-distribution egress: GPU-hours
    /// plus the egress bill for `egress_bytes_per_step` every `step_s`
    /// seconds (one WAN copy per region under the relay tree).
    pub fn tokens_per_dollar_with_egress(
        &self,
        tokens_per_s: f64,
        egress_bytes_per_step: u64,
        step_s: f64,
    ) -> f64 {
        let egress_per_hr = self.egress_cost(egress_bytes_per_step) * 3600.0 / step_s.max(1e-9);
        tokens_per_s * 3600.0 / (self.cost_per_hr() + egress_per_hr)
    }
}

/// Commodity inter-cloud egress rate, $/GB (order-of-magnitude commodity
/// pricing; the paper's cost tables price GPU-hours only, so egress is an
/// additional conservative term against SparrowRL).
pub const EGRESS_PER_GB: f64 = 0.08;

/// The multi-region WAN deployment behind `sparrowrl exp wan` (§7.5 /
/// Fig 13 scaled out): a 4xH100 trainer block plus `actors_per_region`
/// A100 actors in each of `n_regions` regions, all on-demand cross-cloud.
pub fn wan_deployment(n_regions: usize, actors_per_region: usize) -> Deployment {
    Deployment::cross_cloud(
        &format!("4xH100 + {n_regions}x{actors_per_region}xA100 ({n_regions}-region cross-cloud)"),
        vec![
            GpuPool { class: GpuClass::H100, count: 4 },
            GpuPool { class: GpuClass::A100, count: n_regions * actors_per_region },
        ],
    )
}

/// The paper's Table 6 deployment pairs for a given model scale.
pub fn table6_deployments(model: &str) -> Option<(Deployment, Deployment)> {
    match model {
        "qwen3-8b" => Some((
            Deployment::cross_cloud(
                "4xH100 + 8xA100 (cross-cloud on-demand)",
                vec![
                    GpuPool { class: GpuClass::H100, count: 4 },
                    GpuPool { class: GpuClass::A100, count: 8 },
                ],
            ),
            Deployment::reserved_rdma("1x8xH100 RDMA cluster (reserved)", GpuClass::H100, 8),
        )),
        "qwen3-14b" => Some((
            Deployment::cross_cloud(
                "6xH100 + 12xA100 (cross-cloud on-demand)",
                vec![
                    GpuPool { class: GpuClass::H100, count: 6 },
                    GpuPool { class: GpuClass::A100, count: 12 },
                ],
            ),
            Deployment::reserved_rdma("2x8xH100 RDMA cluster (reserved)", GpuClass::H100, 12),
        )),
        _ => None,
    }
}

/// Typed decision emitted by the [`Autoscaler`] at a step boundary
/// (carried on `session::Event::Autoscale`). `marginal_tpd` is the
/// tokens/$ the *next* (or last) actor earns; `reserve_line` is the
/// reserved-RDMA baseline it was compared against.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleDecision {
    /// The marginal actor beats the reserved-RDMA line — grow the fleet.
    Add { marginal_tpd: f64, reserve_line: f64 },
    /// The marginal actor earns less than the line — shrink the fleet.
    Drop { marginal_tpd: f64, reserve_line: f64 },
    /// Inside the hysteresis band, or pinned at the fleet bounds.
    Hold { marginal_tpd: f64, reserve_line: f64 },
}

impl ScaleDecision {
    pub fn name(&self) -> &'static str {
        match self {
            ScaleDecision::Add { .. } => "add",
            ScaleDecision::Drop { .. } => "drop",
            ScaleDecision::Hold { .. } => "hold",
        }
    }

    pub fn marginal_tpd(&self) -> f64 {
        match *self {
            ScaleDecision::Add { marginal_tpd, .. }
            | ScaleDecision::Drop { marginal_tpd, .. }
            | ScaleDecision::Hold { marginal_tpd, .. } => marginal_tpd,
        }
    }

    /// The reserved-RDMA tokens-per-dollar line the decision compared
    /// against (after hysteresis).
    pub fn reserve_line(&self) -> f64 {
        match *self {
            ScaleDecision::Add { reserve_line, .. }
            | ScaleDecision::Drop { reserve_line, .. }
            | ScaleDecision::Hold { reserve_line, .. } => reserve_line,
        }
    }
}

/// Cost-model autoscaling policy (ISSUE 6): elastic actor capacity is
/// worth adding only while the *marginal* actor — its on-demand GPU-hour
/// plus its share of delta-egress — earns more tokens per dollar than
/// the same money buys on a reserved RDMA cluster. The whole fleet is
/// priced through [`wan_deployment`], so the decision moves with the
/// same Table 6 rates as every other cost figure in the repo.
///
/// Decisions are advisory: the runtime logs them as
/// `Event::Autoscale`; the chaos suite and bench read the trace.
#[derive(Clone, Copy, Debug)]
pub struct Autoscaler {
    pub n_regions: usize,
    /// Reserved-RDMA tokens/$ baseline (e.g. from
    /// [`reserved_line`]). Marginal capacity must beat this to be
    /// worth renting.
    pub reserve_line: f64,
    /// Never drop below this many actors per region.
    pub min_per_region: usize,
    /// Never grow past this many actors per region.
    pub max_per_region: usize,
    /// Relative dead-band around the line (e.g. 0.05 = ±5%) so noisy
    /// throughput samples don't flap between Add and Drop.
    pub hysteresis: f64,
}

impl Autoscaler {
    pub fn new(n_regions: usize, reserve_line: f64) -> Autoscaler {
        Autoscaler {
            n_regions,
            reserve_line,
            min_per_region: 1,
            max_per_region: 64,
            hysteresis: 0.05,
        }
    }

    /// Marginal tokens/$ of growing the fleet from `per_region` to
    /// `per_region + 1` actors per region: the throughput the extra
    /// actors add, divided by the extra hourly cost (GPU rate via
    /// [`wan_deployment`] plus the delta-egress each new actor pulls).
    pub fn marginal_tokens_per_dollar(
        &self,
        per_region: usize,
        tokens_per_s_per_actor: f64,
        egress_bytes_per_actor_step: u64,
        step_s: f64,
    ) -> f64 {
        let d0 = wan_deployment(self.n_regions, per_region);
        let d1 = wan_deployment(self.n_regions, per_region + 1);
        let added_actors = self.n_regions as f64;
        let d_tokens = tokens_per_s_per_actor * added_actors;
        let d_gpu_hr = d1.cost_per_hr() - d0.cost_per_hr();
        let d_egress_hr =
            d1.egress_cost(egress_bytes_per_actor_step) * added_actors * 3600.0 / step_s.max(1e-9);
        d_tokens * 3600.0 / (d_gpu_hr + d_egress_hr).max(1e-9)
    }

    /// One policy evaluation at a step boundary. Pure: same inputs,
    /// same decision — the chaos suite relies on this determinism.
    pub fn decide(
        &self,
        per_region: usize,
        tokens_per_s_per_actor: f64,
        egress_bytes_per_actor_step: u64,
        step_s: f64,
    ) -> ScaleDecision {
        let marginal_tpd = self.marginal_tokens_per_dollar(
            per_region,
            tokens_per_s_per_actor,
            egress_bytes_per_actor_step,
            step_s,
        );
        let reserve_line = self.reserve_line;
        let hi = reserve_line * (1.0 + self.hysteresis);
        let lo = reserve_line * (1.0 - self.hysteresis);
        if marginal_tpd > hi && per_region < self.max_per_region {
            ScaleDecision::Add { marginal_tpd, reserve_line }
        } else if marginal_tpd < lo && per_region > self.min_per_region {
            ScaleDecision::Drop { marginal_tpd, reserve_line }
        } else {
            ScaleDecision::Hold { marginal_tpd, reserve_line }
        }
    }
}

/// The reserved-RDMA tokens/$ line for a Table 6 model scale: what the
/// same sustained throughput costs on the reserved cluster. `None` for
/// models without a Table 6 entry.
pub fn reserved_line(model: &str, tokens_per_s: f64) -> Option<f64> {
    table6_deployments(model).map(|(_, rdma)| rdma.tokens_per_dollar(tokens_per_s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_hourly_rates() {
        let (sparrow, single) = table6_deployments("qwen3-8b").unwrap();
        assert!((sparrow.cost_per_hr() - 15.88).abs() < 1e-9);
        assert!((single.cost_per_hr() - 19.92).abs() < 1e-9);
        let (sparrow, single) = table6_deployments("qwen3-14b").unwrap();
        assert!((sparrow.cost_per_hr() - 23.82).abs() < 1e-9);
        assert!((single.cost_per_hr() - 39.84).abs() < 1e-9);
    }

    #[test]
    fn rdma_rounds_up_to_blocks() {
        let d = Deployment::reserved_rdma("x", GpuClass::H100, 12);
        assert_eq!(d.gpu_count(), 16);
        let d = Deployment::reserved_rdma("x", GpuClass::H100, 8);
        assert_eq!(d.gpu_count(), 8);
    }

    #[test]
    fn tokens_per_dollar_matches_paper_magnitude() {
        // Paper: ~15.9k tokens/s at $15.88/hr => ~3.60M tokens/$.
        let (sparrow, _) = table6_deployments("qwen3-8b").unwrap();
        let tpd = sparrow.tokens_per_dollar(15_900.0);
        assert!((3.4e6..3.8e6).contains(&tpd), "{tpd}");
    }

    #[test]
    fn wan_deployment_prices_per_region_actors() {
        let d = wan_deployment(4, 2);
        assert_eq!(d.gpu_count(), 12);
        let expect = 4.0 * GpuClass::H100.on_demand_per_hr()
            + 8.0 * GpuClass::A100.on_demand_per_hr();
        assert!((d.cost_per_hr() - expect).abs() < 1e-9);
        assert_eq!(d.procurement, Procurement::OnDemandCrossCloud);
    }

    #[test]
    fn egress_billed_only_cross_cloud_and_shrinks_tokens_per_dollar() {
        let wan = wan_deployment(4, 2);
        let (_, rdma) = table6_deployments("qwen3-8b").unwrap();
        // 4 regions x 202 MB per step.
        let per_step = 4 * 202_000_000u64;
        assert!((wan.egress_cost(per_step) - 0.8 * 0.08 * 1.01).abs() < 1e-3);
        assert_eq!(rdma.egress_cost(per_step), 0.0);
        let plain = wan.tokens_per_dollar(10_000.0);
        let with = wan.tokens_per_dollar_with_egress(10_000.0, per_step, 60.0);
        assert!(with < plain, "egress must cost something");
        assert!(with > plain * 0.5, "but stays the same order of magnitude");
    }

    #[test]
    fn minimum_commitments_inflate_short_runs() {
        // Table 1's story: an exploratory 2-hour run on reserved RDMA
        // bills 24 hours; on-demand bills 2.
        let (sparrow, single) = table6_deployments("qwen3-8b").unwrap();
        let on_demand = sparrow.run_cost(2.0);
        let reserved = single.run_cost(2.0);
        assert!((on_demand - 2.0 * 15.88).abs() < 1e-9);
        assert!((reserved - 24.0 * 19.92).abs() < 1e-9);
        assert!(reserved / on_demand > 10.0);
    }

    #[test]
    fn autoscaler_adds_when_marginal_beats_line_and_drops_when_it_does_not() {
        let line = reserved_line("qwen3-8b", 15_900.0).unwrap();
        let scaler = Autoscaler::new(2, line);
        // A productive actor: well above the reserved line per dollar.
        let fast = scaler.decide(2, 4_000.0, 10 << 20, 30.0);
        assert!(matches!(fast, ScaleDecision::Add { .. }), "{fast:?}");
        // A nearly idle actor: marginal tokens/$ collapses below it.
        let slow = scaler.decide(2, 100.0, 10 << 20, 30.0);
        assert!(matches!(slow, ScaleDecision::Drop { .. }), "{slow:?}");
        // Fleet bounds pin the decision to Hold even off the line.
        let floor = Autoscaler { min_per_region: 2, ..scaler }.decide(2, 100.0, 10 << 20, 30.0);
        assert!(matches!(floor, ScaleDecision::Hold { .. }), "{floor:?}");
        let ceil = Autoscaler { max_per_region: 2, ..scaler }.decide(2, 4_000.0, 10 << 20, 30.0);
        assert!(matches!(ceil, ScaleDecision::Hold { .. }), "{ceil:?}");
    }

    #[test]
    fn marginal_tpd_is_finite_positive_and_shrinks_with_egress() {
        let scaler = Autoscaler::new(4, 1.0);
        let lean = scaler.marginal_tokens_per_dollar(2, 2_000.0, 0, 30.0);
        let heavy = scaler.marginal_tokens_per_dollar(2, 2_000.0, 500 << 20, 30.0);
        assert!(lean.is_finite() && lean > 0.0);
        assert!(heavy < lean, "egress must tax the marginal actor: {heavy} vs {lean}");
    }

    #[test]
    fn decide_is_deterministic() {
        let scaler = Autoscaler::new(2, reserved_line("qwen3-8b", 15_900.0).unwrap());
        let a = scaler.decide(3, 1_234.5, 42 << 20, 17.0);
        let b = scaler.decide(3, 1_234.5, 42 << 20, 17.0);
        assert_eq!(a, b);
    }
}
