//! Versioned, immutable delta checkpoints and the Trainer's Checkpoint
//! Store (§4, §5.1).
//!
//! Storage and network transfer share one abstraction: a checkpoint is a
//! hashed byte artifact; "transfer" is the replication of that artifact.
//! Partial failures therefore never leave ambiguous state — an actor either
//! holds a hash-verified `D_v` or it does not.

use super::encode::{decode_delta, delta_hash, encode_delta, DecodeError};
use super::store::RecoveryError;
use super::SparseDelta;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// An immutable, hash-identified delta artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaCheckpoint {
    pub version: u64,
    pub base_version: u64,
    pub bytes: Vec<u8>,
    pub hash: [u8; 32],
}

impl DeltaCheckpoint {
    /// Seal a sparse delta into its canonical artifact.
    pub fn seal(delta: &SparseDelta) -> DeltaCheckpoint {
        let bytes = encode_delta(delta);
        let hash = delta_hash(&bytes).expect("encoded delta always carries a hash");
        DeltaCheckpoint {
            version: delta.version,
            base_version: delta.base_version,
            bytes,
            hash,
        }
    }

    /// Re-open the artifact, verifying integrity.
    pub fn open(&self) -> Result<SparseDelta, DecodeError> {
        decode_delta(&self.bytes)
    }

    /// Reconstruct from raw bytes (e.g. after network reassembly).
    pub fn from_bytes(bytes: Vec<u8>) -> Result<DeltaCheckpoint, DecodeError> {
        let d = decode_delta(&bytes)?;
        let hash = delta_hash(&bytes).ok_or(DecodeError::Truncated)?;
        Ok(DeltaCheckpoint {
            version: d.version,
            base_version: d.base_version,
            bytes,
            hash,
        })
    }

    pub fn payload_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }

    pub fn short_hash(&self) -> String {
        crate::util::hex(&self.hash[..6])
    }
}

/// The Trainer Hub's Checkpoint Store: versioned deltas plus optional
/// on-disk persistence. Checkpoints are append-only; `gc_before` trims the
/// history once all actors have advanced (one-step lag keeps this tiny).
pub struct CheckpointStore {
    dir: Option<PathBuf>,
    by_version: BTreeMap<u64, DeltaCheckpoint>,
    /// Chain horizons pinned by in-flight delta-chain bootstraps
    /// (horizon version -> pin count). While any pin is held, gc keeps
    /// the whole chain D_1.. so a joiner's replay cannot lose links.
    pins: BTreeMap<u64, usize>,
}

impl CheckpointStore {
    /// Memory-only store (simulation and tests).
    pub fn in_memory() -> CheckpointStore {
        CheckpointStore { dir: None, by_version: BTreeMap::new(), pins: BTreeMap::new() }
    }

    /// Store persisting artifacts as `<dir>/delta-v{N}.sprw`. Sweeps
    /// orphaned `.delta-v{N}.tmp` files a crash mid-`put` left behind —
    /// the rename never happened, so they are dead bytes.
    pub fn on_disk(dir: &Path) -> std::io::Result<CheckpointStore> {
        std::fs::create_dir_all(dir)?;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if name.starts_with(".delta-v") && name.ends_with(".tmp") {
                std::fs::remove_file(&path)?;
            }
        }
        Ok(CheckpointStore {
            dir: Some(dir.to_path_buf()),
            by_version: BTreeMap::new(),
            pins: BTreeMap::new(),
        })
    }

    /// Insert a sealed checkpoint. Re-inserting the same version must carry
    /// the same hash (immutability); differing bytes are an error.
    pub fn put(&mut self, ckpt: DeltaCheckpoint) -> std::io::Result<()> {
        if let Some(existing) = self.by_version.get(&ckpt.version) {
            if existing.hash != ckpt.hash {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::AlreadyExists,
                    format!("version {} already sealed with a different hash", ckpt.version),
                ));
            }
            return Ok(());
        }
        if let Some(dir) = &self.dir {
            let path = dir.join(format!("delta-v{}.sprw", ckpt.version));
            let tmp = dir.join(format!(".delta-v{}.tmp", ckpt.version));
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&ckpt.bytes)?;
            f.sync_all()?;
            std::fs::rename(&tmp, &path)?;
        }
        self.by_version.insert(ckpt.version, ckpt);
        Ok(())
    }

    pub fn get(&self, version: u64) -> Option<&DeltaCheckpoint> {
        self.by_version.get(&version)
    }

    pub fn latest_version(&self) -> Option<u64> {
        self.by_version.keys().next_back().copied()
    }

    pub fn len(&self) -> usize {
        self.by_version.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_version.is_empty()
    }

    /// Load any persisted checkpoints from disk (crash recovery).
    ///
    /// An artifact is admitted only when the version in its filename
    /// matches the version decoded from its header — a renamed or
    /// misplaced artifact is rejected with
    /// [`RecoveryError::VersionMismatch`] instead of being silently
    /// inserted under whatever its header claims.
    pub fn recover(&mut self) -> Result<usize, RecoveryError> {
        let Some(dir) = self.dir.clone() else { return Ok(0) };
        let mut n = 0;
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            let Some(filename_version) = name
                .strip_prefix("delta-v")
                .and_then(|s| s.strip_suffix(".sprw"))
                .and_then(|s| s.parse::<u64>().ok())
            else {
                continue;
            };
            let bytes = std::fs::read(&path)?;
            let ckpt = DeltaCheckpoint::from_bytes(bytes)
                .map_err(|error| RecoveryError::CorruptArtifact { path: path.clone(), error })?;
            if ckpt.version != filename_version {
                return Err(RecoveryError::VersionMismatch {
                    path,
                    filename_version,
                    header_version: ckpt.version,
                });
            }
            self.by_version.entry(ckpt.version).or_insert(ckpt);
            n += 1;
        }
        Ok(n)
    }

    /// Pin the chain `D_1..=horizon` against gc while a delta-chain
    /// bootstrap replays it. Pins are counted, so overlapping joins on
    /// the same horizon are safe.
    pub fn pin_chain(&mut self, horizon: u64) {
        *self.pins.entry(horizon).or_insert(0) += 1;
    }

    /// Release one pin on `horizon`. Unmatched unpins are ignored.
    pub fn unpin_chain(&mut self, horizon: u64) {
        if let Some(count) = self.pins.get_mut(&horizon) {
            *count -= 1;
            if *count == 0 {
                self.pins.remove(&horizon);
            }
        }
    }

    /// Drop checkpoints with version < `min_version`. While any chain
    /// pin is held the floor is clamped to 1 (a bootstrap replays from
    /// D_1, so nothing may be collected). A failed disk delete keeps
    /// the in-memory entry too — the store never claims a checkpoint is
    /// gone while its artifact may still be on disk.
    pub fn gc_before(&mut self, min_version: u64) -> std::io::Result<usize> {
        let min_version = if self.pins.is_empty() { min_version } else { min_version.min(1) };
        let drop: Vec<u64> = self
            .by_version
            .range(..min_version)
            .map(|(&v, _)| v)
            .collect();
        let mut removed = 0;
        for v in &drop {
            if let Some(dir) = &self.dir {
                match std::fs::remove_file(dir.join(format!("delta-v{v}.sprw"))) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(e),
                }
            }
            self.by_version.remove(v);
            removed += 1;
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, ApplyMode, ModelLayout, ParamSet};
    use crate::util::Rng;

    fn ckpt(version: u64, seed: u64) -> DeltaCheckpoint {
        let l = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(seed);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        let t0 = &mut new.tensors[0];
        let i = rng.range(0, t0.len());
        t0[i] = crate::util::Bf16::from_bits(t0[i].to_bits() ^ 1);
        DeltaCheckpoint::seal(&extract_delta(&l, &old, &new, version - 1, version, ApplyMode::Assign))
    }

    #[test]
    fn seal_open_round_trip() {
        let c = ckpt(3, 1);
        let d = c.open().unwrap();
        assert_eq!(d.version, 3);
        assert_eq!(d.base_version, 2);
        assert_eq!(c.hash, super::super::encode::delta_hash(&c.bytes).unwrap());
    }

    #[test]
    fn store_immutability_enforced() {
        let mut s = CheckpointStore::in_memory();
        let c1 = ckpt(1, 1);
        let c1_different = ckpt(1, 99);
        s.put(c1.clone()).unwrap();
        assert!(s.put(c1.clone()).is_ok(), "idempotent re-put allowed");
        assert!(s.put(c1_different).is_err(), "conflicting bytes rejected");
        assert_eq!(s.latest_version(), Some(1));
    }

    /// Per-test unique temp dir: keyed on pid AND test name, because
    /// cargo runs all tests in one process and pid alone collides.
    fn test_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("sprw-ckpt-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn disk_persistence_and_recovery() {
        let dir = test_dir("recov");
        {
            let mut s = CheckpointStore::on_disk(&dir).unwrap();
            s.put(ckpt(1, 1)).unwrap();
            s.put(ckpt(2, 2)).unwrap();
        }
        let mut s2 = CheckpointStore::on_disk(&dir).unwrap();
        assert_eq!(s2.recover().unwrap(), 2);
        assert_eq!(s2.latest_version(), Some(2));
        assert!(s2.get(1).unwrap().open().is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupted_disk_artifact_fails_recovery() {
        let dir = test_dir("corrupt");
        {
            let mut s = CheckpointStore::on_disk(&dir).unwrap();
            s.put(ckpt(1, 1)).unwrap();
        }
        // Flip a byte in the stored artifact.
        let path = dir.join("delta-v1.sprw");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let mut s2 = CheckpointStore::on_disk(&dir).unwrap();
        assert!(matches!(s2.recover(), Err(RecoveryError::CorruptArtifact { .. })));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recovery_rejects_filename_header_mismatch() {
        let dir = test_dir("mismatch");
        {
            let mut s = CheckpointStore::on_disk(&dir).unwrap();
            s.put(ckpt(3, 1)).unwrap();
        }
        // Rename v3's artifact to claim v7: recovery must refuse rather
        // than trust either name.
        std::fs::rename(dir.join("delta-v3.sprw"), dir.join("delta-v7.sprw")).unwrap();
        let mut s2 = CheckpointStore::on_disk(&dir).unwrap();
        match s2.recover() {
            Err(RecoveryError::VersionMismatch { filename_version, header_version, .. }) => {
                assert_eq!(filename_version, 7);
                assert_eq!(header_version, 3);
            }
            other => panic!("expected VersionMismatch, got {:?}", other.err()),
        }
        assert!(s2.is_empty(), "nothing may be admitted from a mismatched artifact");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn on_disk_sweeps_orphaned_tmp_files() {
        let dir = test_dir("sweep");
        {
            let mut s = CheckpointStore::on_disk(&dir).unwrap();
            s.put(ckpt(1, 1)).unwrap();
        }
        // A crash mid-put leaves a tmp that never got renamed.
        std::fs::write(dir.join(".delta-v2.tmp"), b"partial").unwrap();
        let mut s2 = CheckpointStore::on_disk(&dir).unwrap();
        assert!(!dir.join(".delta-v2.tmp").exists(), "orphaned tmp must be swept");
        assert_eq!(s2.recover().unwrap(), 1, "real artifacts survive the sweep");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_trims_history() {
        let mut s = CheckpointStore::in_memory();
        for v in 1..=5 {
            s.put(ckpt(v, v)).unwrap();
        }
        assert_eq!(s.gc_before(4).unwrap(), 3);
        assert!(s.get(3).is_none());
        assert!(s.get(4).is_some());
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn pinned_chain_blocks_gc() {
        let mut s = CheckpointStore::in_memory();
        for v in 1..=5 {
            s.put(ckpt(v, v)).unwrap();
        }
        s.pin_chain(4);
        assert_eq!(s.gc_before(4).unwrap(), 0, "pinned chain must not be collected");
        assert!(s.get(1).is_some());
        s.pin_chain(4); // a second overlapping join
        s.unpin_chain(4);
        assert_eq!(s.gc_before(4).unwrap(), 0, "still pinned by the second join");
        s.unpin_chain(4);
        assert_eq!(s.gc_before(4).unwrap(), 3, "gc proceeds once all pins drop");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn gc_missing_disk_artifact_is_not_an_error() {
        let dir = test_dir("gc-missing");
        let mut s = CheckpointStore::on_disk(&dir).unwrap();
        for v in 1..=3 {
            s.put(ckpt(v, v)).unwrap();
        }
        // Someone already removed v1's file out from under the store.
        std::fs::remove_file(dir.join("delta-v1.sprw")).unwrap();
        assert_eq!(s.gc_before(3).unwrap(), 2);
        assert_eq!(s.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
