//! Versioned, immutable delta checkpoints and the Trainer's Checkpoint
//! Store (§4, §5.1).
//!
//! Storage and network transfer share one abstraction: a checkpoint is a
//! hashed byte artifact; "transfer" is the replication of that artifact.
//! Partial failures therefore never leave ambiguous state — an actor either
//! holds a hash-verified `D_v` or it does not.

use super::encode::{decode_delta, delta_hash, encode_delta, DecodeError};
use super::SparseDelta;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// An immutable, hash-identified delta artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaCheckpoint {
    pub version: u64,
    pub base_version: u64,
    pub bytes: Vec<u8>,
    pub hash: [u8; 32],
}

impl DeltaCheckpoint {
    /// Seal a sparse delta into its canonical artifact.
    pub fn seal(delta: &SparseDelta) -> DeltaCheckpoint {
        let bytes = encode_delta(delta);
        let hash = delta_hash(&bytes).expect("encoded delta always carries a hash");
        DeltaCheckpoint {
            version: delta.version,
            base_version: delta.base_version,
            bytes,
            hash,
        }
    }

    /// Re-open the artifact, verifying integrity.
    pub fn open(&self) -> Result<SparseDelta, DecodeError> {
        decode_delta(&self.bytes)
    }

    /// Reconstruct from raw bytes (e.g. after network reassembly).
    pub fn from_bytes(bytes: Vec<u8>) -> Result<DeltaCheckpoint, DecodeError> {
        let d = decode_delta(&bytes)?;
        let hash = delta_hash(&bytes).ok_or(DecodeError::Truncated)?;
        Ok(DeltaCheckpoint {
            version: d.version,
            base_version: d.base_version,
            bytes,
            hash,
        })
    }

    pub fn payload_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }

    pub fn short_hash(&self) -> String {
        crate::util::hex(&self.hash[..6])
    }
}

/// The Trainer Hub's Checkpoint Store: versioned deltas plus optional
/// on-disk persistence. Checkpoints are append-only; `gc_before` trims the
/// history once all actors have advanced (one-step lag keeps this tiny).
pub struct CheckpointStore {
    dir: Option<PathBuf>,
    by_version: BTreeMap<u64, DeltaCheckpoint>,
}

impl CheckpointStore {
    /// Memory-only store (simulation and tests).
    pub fn in_memory() -> CheckpointStore {
        CheckpointStore { dir: None, by_version: BTreeMap::new() }
    }

    /// Store persisting artifacts as `<dir>/delta-v{N}.sprw`.
    pub fn on_disk(dir: &Path) -> std::io::Result<CheckpointStore> {
        std::fs::create_dir_all(dir)?;
        Ok(CheckpointStore { dir: Some(dir.to_path_buf()), by_version: BTreeMap::new() })
    }

    /// Insert a sealed checkpoint. Re-inserting the same version must carry
    /// the same hash (immutability); differing bytes are an error.
    pub fn put(&mut self, ckpt: DeltaCheckpoint) -> std::io::Result<()> {
        if let Some(existing) = self.by_version.get(&ckpt.version) {
            if existing.hash != ckpt.hash {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::AlreadyExists,
                    format!("version {} already sealed with a different hash", ckpt.version),
                ));
            }
            return Ok(());
        }
        if let Some(dir) = &self.dir {
            let path = dir.join(format!("delta-v{}.sprw", ckpt.version));
            let tmp = dir.join(format!(".delta-v{}.tmp", ckpt.version));
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&ckpt.bytes)?;
            f.sync_all()?;
            std::fs::rename(&tmp, &path)?;
        }
        self.by_version.insert(ckpt.version, ckpt);
        Ok(())
    }

    pub fn get(&self, version: u64) -> Option<&DeltaCheckpoint> {
        self.by_version.get(&version)
    }

    pub fn latest_version(&self) -> Option<u64> {
        self.by_version.keys().next_back().copied()
    }

    pub fn len(&self) -> usize {
        self.by_version.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_version.is_empty()
    }

    /// Load any persisted checkpoints from disk (crash recovery).
    pub fn recover(&mut self) -> std::io::Result<usize> {
        let Some(dir) = self.dir.clone() else { return Ok(0) };
        let mut n = 0;
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if !name.starts_with("delta-v") || !name.ends_with(".sprw") {
                continue;
            }
            let bytes = std::fs::read(&path)?;
            match DeltaCheckpoint::from_bytes(bytes) {
                Ok(ckpt) => {
                    self.by_version.entry(ckpt.version).or_insert(ckpt);
                    n += 1;
                }
                Err(e) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("{}: {e}", path.display()),
                    ));
                }
            }
        }
        Ok(n)
    }

    /// Drop checkpoints with version < `min_version`.
    pub fn gc_before(&mut self, min_version: u64) -> usize {
        let drop: Vec<u64> = self
            .by_version
            .range(..min_version)
            .map(|(&v, _)| v)
            .collect();
        for v in &drop {
            if let Some(dir) = &self.dir {
                let _ = std::fs::remove_file(dir.join(format!("delta-v{v}.sprw")));
            }
            self.by_version.remove(v);
        }
        drop.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, ApplyMode, ModelLayout, ParamSet};
    use crate::util::Rng;

    fn ckpt(version: u64, seed: u64) -> DeltaCheckpoint {
        let l = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(seed);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        let t0 = &mut new.tensors[0];
        let i = rng.range(0, t0.len());
        t0[i] = crate::util::Bf16::from_bits(t0[i].to_bits() ^ 1);
        DeltaCheckpoint::seal(&extract_delta(&l, &old, &new, version - 1, version, ApplyMode::Assign))
    }

    #[test]
    fn seal_open_round_trip() {
        let c = ckpt(3, 1);
        let d = c.open().unwrap();
        assert_eq!(d.version, 3);
        assert_eq!(d.base_version, 2);
        assert_eq!(c.hash, super::super::encode::delta_hash(&c.bytes).unwrap());
    }

    #[test]
    fn store_immutability_enforced() {
        let mut s = CheckpointStore::in_memory();
        let c1 = ckpt(1, 1);
        let c1_different = ckpt(1, 99);
        s.put(c1.clone()).unwrap();
        assert!(s.put(c1.clone()).is_ok(), "idempotent re-put allowed");
        assert!(s.put(c1_different).is_err(), "conflicting bytes rejected");
        assert_eq!(s.latest_version(), Some(1));
    }

    #[test]
    fn disk_persistence_and_recovery() {
        let dir = std::env::temp_dir().join(format!("sprw-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut s = CheckpointStore::on_disk(&dir).unwrap();
            s.put(ckpt(1, 1)).unwrap();
            s.put(ckpt(2, 2)).unwrap();
        }
        let mut s2 = CheckpointStore::on_disk(&dir).unwrap();
        assert_eq!(s2.recover().unwrap(), 2);
        assert_eq!(s2.latest_version(), Some(2));
        assert!(s2.get(1).unwrap().open().is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupted_disk_artifact_fails_recovery() {
        let dir = std::env::temp_dir().join(format!("sprw-corrupt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut s = CheckpointStore::on_disk(&dir).unwrap();
            s.put(ckpt(1, 1)).unwrap();
        }
        // Flip a byte in the stored artifact.
        let path = dir.join("delta-v1.sprw");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let mut s2 = CheckpointStore::on_disk(&dir).unwrap();
        assert!(s2.recover().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_trims_history() {
        let mut s = CheckpointStore::in_memory();
        for v in 1..=5 {
            s.put(ckpt(v, v)).unwrap();
        }
        assert_eq!(s.gc_before(4), 3);
        assert!(s.get(3).is_none());
        assert!(s.get(4).is_some());
        assert_eq!(s.len(), 2);
    }
}
