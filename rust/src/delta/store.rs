//! Durable, content-addressed checkpoint store with a crash-consistent
//! run journal.
//!
//! Layout under a persist directory:
//!
//! ```text
//! persist_dir/
//!   objects/<sha256-hex>.sprw   immutable blobs, named by the SHA-256 of
//!                               their full byte content (delta artifacts,
//!                               base policy snapshot, trainer-state dumps,
//!                               compacted chains)
//!   refs/v0                     JSON manifest: base snapshot + train state
//!   refs/v{N}                   JSON manifest: delta object + train state
//!   refs/compact                JSON manifest: folded chain D_1..D_k
//!   journal.jsonl               append-only run journal (one JSON/line)
//! ```
//!
//! Crash-consistency protocol, per commit of version `V`:
//!
//! 1. write the delta object (tmp + fsync + rename),
//! 2. write the trainer-state object (tmp + fsync + rename),
//! 3. write `refs/v{V}` (tmp + fsync + rename),
//! 4. append one journal line and fsync the journal.
//!
//! Step 4 is the commit point. A crash anywhere before it leaves sealed
//! but unjournaled artifacts that recovery ignores; the resumed run
//! recommits the same version idempotently (object writes to an existing
//! content address are skipped, the manifest rewrite is byte-identical,
//! and the journal gains the record that was lost). A torn final journal
//! line (the classic crash-during-append) is truncated away silently;
//! corruption anywhere else surfaces as a typed [`RecoveryError`].

use std::collections::BTreeMap;
use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use sha2::{Digest, Sha256};

use crate::delta::encode::DecodeError;
use crate::delta::{ApplyMode, DeltaCheckpoint, ModelLayout, ParamSet, SparseDelta, TensorDelta};
use crate::runtime::TrainState;
use crate::util::jsonl::Json;
use crate::util::{hex, Bf16};

/// Typed failure surfaced by [`DurableStore`] recovery and reads.
///
/// Every variant names the artifact that failed so operators can decide
/// between restoring from a replica and accepting data loss; nothing in
/// the recovery path panics.
#[derive(Debug)]
pub enum RecoveryError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// A journal line other than a torn tail failed to parse or had the
    /// wrong schema.
    CorruptJournal {
        /// 0-based line number in `journal.jsonl`.
        line: usize,
        /// Human-readable parse/schema failure.
        reason: String,
    },
    /// The journal has commit records but no leading genesis record.
    MissingGenesis,
    /// Journal versions must be 0, 1, 2, ... with no gaps.
    NonContiguous {
        /// The version recovery expected next.
        expected: u64,
        /// The version actually found.
        found: u64,
    },
    /// A journaled version has no `refs/v{N}` manifest.
    MissingManifest {
        /// The version whose manifest is missing.
        version: u64,
    },
    /// A manifest exists but is unreadable or inconsistent.
    CorruptManifest {
        /// The version whose manifest is corrupt.
        version: u64,
        /// Human-readable reason.
        reason: String,
    },
    /// A manifest references an object that is not on disk.
    MissingObject {
        /// The version whose manifest references the object.
        version: u64,
        /// Content address (SHA-256 hex) of the missing object.
        id: String,
    },
    /// An object's bytes no longer hash to its content address.
    ObjectHashMismatch {
        /// The version whose manifest references the object.
        version: u64,
        /// Content address the object was stored under.
        id: String,
    },
    /// A reconstructed policy's checksum differs from the journaled
    /// witness recorded at commit time.
    WitnessMismatch {
        /// The version whose witness failed to verify.
        version: u64,
    },
    /// A version was requested that the journal does not record.
    UnknownVersion {
        /// The requested version.
        version: u64,
    },
    /// The persisted run's identity (model fingerprint / run seed) does
    /// not match the resuming configuration.
    ConfigMismatch {
        /// Which field disagreed (e.g. `"model_fp"`, `"run_seed"`).
        field: &'static str,
    },
    /// Chain compaction failed.
    Compaction(MergeError),
    /// A delta artifact failed to decode.
    CorruptArtifact {
        /// Path of the artifact.
        path: PathBuf,
        /// Decoder failure.
        error: DecodeError,
    },
    /// A `delta-v{N}.sprw` filename disagrees with the version in its
    /// decoded header (legacy [`CheckpointStore`] layout).
    ///
    /// [`CheckpointStore`]: crate::delta::CheckpointStore
    VersionMismatch {
        /// Path of the artifact.
        path: PathBuf,
        /// Version encoded in the filename.
        filename_version: u64,
        /// Version decoded from the artifact header.
        header_version: u64,
    },
    /// A directory holding a single-run [`DurableStore`] layout (or
    /// nothing at all) was opened as a multi-run model registry.
    NotARegistry {
        /// The offending directory.
        path: PathBuf,
    },
    /// A directory holding a multi-run registry layout was opened as a
    /// single-run [`DurableStore`] persist dir.
    NotARun {
        /// The offending directory.
        path: PathBuf,
    },
    /// The registry has no model published under this name.
    UnknownModel {
        /// The requested model name.
        model: String,
    },
    /// The registry's model exists but has no such published version.
    UnknownModelVersion {
        /// The model whose version was requested.
        model: String,
        /// The requested version.
        version: u64,
    },
    /// A registry operation crossed base objects or model fingerprints:
    /// the named model's shared base does not match the caller's (a swap
    /// composition is only defined between fine-tunes off one base).
    BaseMismatch {
        /// The model whose base disagreed.
        model: String,
        /// Human-readable detail (which identity field disagreed).
        reason: String,
    },
    /// Publishing would contradict what the registry already records for
    /// this model (different base, fingerprint, or conflicting bytes for
    /// an already-published version).
    RegistryConflict {
        /// The model being published.
        model: String,
        /// Human-readable detail.
        reason: String,
    },
}

impl std::fmt::Display for RecoveryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecoveryError::Io(e) => write!(f, "store io error: {e}"),
            RecoveryError::CorruptJournal { line, reason } => {
                write!(f, "corrupt journal record at line {line}: {reason}")
            }
            RecoveryError::MissingGenesis => {
                write!(f, "journal has commit records but no genesis record")
            }
            RecoveryError::NonContiguous { expected, found } => {
                write!(f, "journal is non-contiguous: expected v{expected}, found v{found}")
            }
            RecoveryError::MissingManifest { version } => {
                write!(f, "missing manifest refs/v{version}")
            }
            RecoveryError::CorruptManifest { version, reason } => {
                write!(f, "corrupt manifest refs/v{version}: {reason}")
            }
            RecoveryError::MissingObject { version, id } => {
                write!(f, "v{version} references missing object {id}")
            }
            RecoveryError::ObjectHashMismatch { version, id } => {
                write!(f, "object {id} (referenced by v{version}) fails its content hash")
            }
            RecoveryError::WitnessMismatch { version } => {
                write!(f, "reconstructed v{version} does not match its journaled witness")
            }
            RecoveryError::UnknownVersion { version } => {
                write!(f, "version v{version} is not recorded in the journal")
            }
            RecoveryError::ConfigMismatch { field } => {
                write!(f, "persisted run does not match the resuming config: {field} differs")
            }
            RecoveryError::Compaction(e) => write!(f, "chain compaction failed: {e}"),
            RecoveryError::CorruptArtifact { path, error } => {
                write!(f, "corrupt delta artifact {}: {error:?}", path.display())
            }
            RecoveryError::VersionMismatch { path, filename_version, header_version } => {
                write!(
                    f,
                    "artifact {} claims v{filename_version} by filename but v{header_version} by header",
                    path.display()
                )
            }
            RecoveryError::NotARegistry { path } => {
                write!(
                    f,
                    "{} is not a model registry (it holds a single-run durable store; \
                     point `registry` commands at a registry directory)",
                    path.display()
                )
            }
            RecoveryError::NotARun { path } => {
                write!(
                    f,
                    "{} is not a single-run persist dir (it holds a model registry; \
                     use `reconstruct --model NAME` for registry reconstruction)",
                    path.display()
                )
            }
            RecoveryError::UnknownModel { model } => {
                write!(f, "registry has no model named {model:?}")
            }
            RecoveryError::UnknownModelVersion { model, version } => {
                write!(f, "model {model:?} has no published version v{version}")
            }
            RecoveryError::BaseMismatch { model, reason } => {
                write!(f, "model {model:?} base mismatch: {reason}")
            }
            RecoveryError::RegistryConflict { model, reason } => {
                write!(f, "publishing {model:?} conflicts with the registry: {reason}")
            }
        }
    }
}

impl std::error::Error for RecoveryError {}

impl From<std::io::Error> for RecoveryError {
    fn from(e: std::io::Error) -> Self {
        RecoveryError::Io(e)
    }
}

impl From<MergeError> for RecoveryError {
    fn from(e: MergeError) -> Self {
        RecoveryError::Compaction(e)
    }
}

/// Typed failure from [`merge_chain`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MergeError {
    /// An empty chain cannot be folded.
    Empty,
    /// Folding is only bit-exact for `ApplyMode::Assign` deltas.
    AddMode {
        /// The offending delta's version.
        version: u64,
    },
    /// Chain links must satisfy `d[i].base_version == d[i-1].version`.
    NonContiguous {
        /// The base version the next link was expected to have.
        expected: u64,
        /// The base version actually found.
        found: u64,
    },
    /// Deltas in a chain must share one model fingerprint.
    ModelMismatch,
}

impl std::fmt::Display for MergeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MergeError::Empty => write!(f, "cannot merge an empty chain"),
            MergeError::AddMode { version } => {
                write!(f, "delta v{version} uses Add mode; only Assign chains fold bit-exactly")
            }
            MergeError::NonContiguous { expected, found } => {
                write!(f, "chain link expected base v{expected}, found base v{found}")
            }
            MergeError::ModelMismatch => write!(f, "chain spans different model fingerprints"),
        }
    }
}

impl std::error::Error for MergeError {}

/// Fold a contiguous Assign-mode chain `D_1..D_k` into one delta whose
/// application is bit-identical to applying the chain sequentially.
///
/// Assign semantics make this a last-writer-wins union per (tensor,
/// index): later deltas overwrite earlier writes to the same slot, and
/// slots written once keep their value. The result spans
/// `chain.first().base_version .. chain.last().version`.
pub fn merge_chain(chain: &[SparseDelta]) -> Result<SparseDelta, MergeError> {
    let first = chain.first().ok_or(MergeError::Empty)?;
    let model_fp = first.model_fp;
    let mut expected_base = first.base_version;
    // tensor id -> (flat index -> latest value). BTreeMaps keep the
    // output sorted, matching the encoder's canonical ordering.
    let mut folded: BTreeMap<u32, BTreeMap<u64, Bf16>> = BTreeMap::new();
    for d in chain {
        if d.mode != ApplyMode::Assign {
            return Err(MergeError::AddMode { version: d.version });
        }
        if d.model_fp != model_fp {
            return Err(MergeError::ModelMismatch);
        }
        if d.base_version != expected_base {
            return Err(MergeError::NonContiguous {
                expected: expected_base,
                found: d.base_version,
            });
        }
        expected_base = d.version;
        for t in &d.tensors {
            let slot = folded.entry(t.tensor).or_default();
            for (i, v) in t.idx.iter().zip(t.vals.iter()) {
                slot.insert(*i, *v);
            }
        }
    }
    let tensors = folded
        .into_iter()
        .filter(|(_, slots)| !slots.is_empty())
        .map(|(tensor, slots)| {
            let mut idx = Vec::with_capacity(slots.len());
            let mut vals = Vec::with_capacity(slots.len());
            for (i, v) in slots {
                idx.push(i);
                vals.push(v);
            }
            TensorDelta { tensor, idx, vals }
        })
        .collect();
    Ok(SparseDelta {
        version: chain.last().unwrap().version,
        base_version: first.base_version,
        model_fp,
        mode: ApplyMode::Assign,
        tensors,
    })
}

/// SHA-256 policy witness: digest of every tensor's bf16 little-endian
/// bytes in layout order. Bit-for-bit the same digest as the pipeline's
/// committed-checksum trace (`rt::pipeline::policy_checksum`), so a
/// journaled witness can be checked against any reconstruction.
pub fn policy_witness(p: &ParamSet) -> [u8; 32] {
    let mut h = Sha256::new();
    let mut buf: Vec<u8> = Vec::new();
    for t in &p.tensors {
        buf.clear();
        buf.reserve(t.len() * 2);
        for b in t {
            buf.extend_from_slice(&b.to_bits().to_le_bytes());
        }
        h.update(&buf);
    }
    h.finalize()
}

const TRAIN_STATE_MAGIC: &[u8; 4] = b"SPTS";

/// Serialize the full-precision trainer state (f32 masters + Adam
/// moments + step counter). The bf16 policy alone cannot resume
/// training bit-exactly: `TrainState::to_policy()` is lossy.
pub fn encode_train_state(state: &TrainState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(TRAIN_STATE_MAGIC);
    out.extend_from_slice(&(state.masters.len() as u32).to_le_bytes());
    for group in [&state.masters, &state.m, &state.v] {
        for tensor in group.iter() {
            out.extend_from_slice(&(tensor.len() as u64).to_le_bytes());
            for x in tensor {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out.extend_from_slice(&state.step.to_le_bytes());
    out
}

/// Inverse of [`encode_train_state`]. Rejects truncated or mislabeled
/// buffers with a readable reason.
pub fn decode_train_state(bytes: &[u8]) -> Result<TrainState, String> {
    let mut pos = 0usize;
    fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], String> {
        if *pos + n > bytes.len() {
            return Err(format!("train state truncated at byte {}", *pos));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    }
    if take(bytes, &mut pos, 4)? != TRAIN_STATE_MAGIC {
        return Err("bad train-state magic".into());
    }
    let n_tensors = u32::from_le_bytes(take(bytes, &mut pos, 4)?.try_into().unwrap()) as usize;
    let mut groups: Vec<Vec<Vec<f32>>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut group = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let len =
                u64::from_le_bytes(take(bytes, &mut pos, 8)?.try_into().unwrap()) as usize;
            let raw = take(bytes, &mut pos, len * 4)?;
            let mut tensor = Vec::with_capacity(len);
            for chunk in raw.chunks_exact(4) {
                tensor.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            group.push(tensor);
        }
        groups.push(group);
    }
    let step = u64::from_le_bytes(take(bytes, &mut pos, 8)?.try_into().unwrap());
    if pos != bytes.len() {
        return Err(format!("train state has {} trailing bytes", bytes.len() - pos));
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let masters = groups.pop().unwrap();
    Ok(TrainState { masters, m, v, step })
}

/// One per-actor RNG seed recorded at a commit boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeedRecord {
    /// Actor id.
    pub actor: u32,
    /// The `job_seed` that actor's generation used for the trained step.
    pub seed: u64,
}

/// One journal line. The journal is the run's commit log: a version
/// exists iff its record does.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    /// Written once when a fresh run first persists: v0 identity.
    Genesis {
        /// SHA-256 policy witness of the base (v0) policy.
        witness: [u8; 32],
        /// Task counter at the start of RL (after SFT warmup).
        task_counter: u64,
        /// Model layout fingerprint; guards resume against a different model.
        model_fp: u64,
        /// Run-level RNG seed; guards resume against a different seed.
        run_seed: u64,
    },
    /// Written at each commit boundary, after the version's objects and
    /// manifest are durable.
    Commit {
        /// Committed policy version.
        version: u64,
        /// The training step whose batch produced this version.
        step: u64,
        /// SHA-256 policy witness of the committed policy.
        witness: [u8; 32],
        /// Task counter after this commit's generation planning.
        task_counter: u64,
        /// Per-actor generation seeds for the trained batch.
        seeds: Vec<SeedRecord>,
    },
}

impl JournalRecord {
    fn to_json(&self) -> Json {
        match self {
            JournalRecord::Genesis { witness, task_counter, model_fp, run_seed } => Json::obj()
                .set("kind", "genesis")
                .set("version", 0u64)
                .set("witness", hex(witness))
                .set("task_counter", *task_counter)
                .set("model_fp", format!("{model_fp:016x}"))
                .set("run_seed", format!("{run_seed:016x}")),
            JournalRecord::Commit { version, step, witness, task_counter, seeds } => {
                let seeds_json: Vec<Json> = seeds
                    .iter()
                    .map(|s| {
                        Json::obj()
                            .set("actor", s.actor)
                            .set("seed", format!("{:016x}", s.seed))
                    })
                    .collect();
                Json::obj()
                    .set("kind", "commit")
                    .set("version", *version)
                    .set("step", *step)
                    .set("witness", hex(witness))
                    .set("task_counter", *task_counter)
                    .set("seeds", Json::Arr(seeds_json))
            }
        }
    }

    fn from_json(j: &Json) -> Result<JournalRecord, String> {
        let kind = j.get("kind").and_then(Json::as_str).ok_or("missing kind")?;
        let witness_hex = j.get("witness").and_then(Json::as_str).ok_or("missing witness")?;
        let witness = parse_hash(witness_hex).ok_or("witness is not 64 hex chars")?;
        let task_counter =
            j.get("task_counter").and_then(Json::as_u64).ok_or("missing task_counter")?;
        match kind {
            "genesis" => {
                let model_fp = j
                    .get("model_fp")
                    .and_then(Json::as_str)
                    .and_then(parse_u64_hex)
                    .ok_or("missing model_fp")?;
                let run_seed = j
                    .get("run_seed")
                    .and_then(Json::as_str)
                    .and_then(parse_u64_hex)
                    .ok_or("missing run_seed")?;
                Ok(JournalRecord::Genesis { witness, task_counter, model_fp, run_seed })
            }
            "commit" => {
                let version = j.get("version").and_then(Json::as_u64).ok_or("missing version")?;
                let step = j.get("step").and_then(Json::as_u64).ok_or("missing step")?;
                let seeds_json = j.get("seeds").and_then(Json::as_arr).ok_or("missing seeds")?;
                let mut seeds = Vec::with_capacity(seeds_json.len());
                for s in seeds_json {
                    let actor =
                        s.get("actor").and_then(Json::as_u64).ok_or("seed missing actor")? as u32;
                    let seed = s
                        .get("seed")
                        .and_then(Json::as_str)
                        .and_then(parse_u64_hex)
                        .ok_or("seed missing seed")?;
                    seeds.push(SeedRecord { actor, seed });
                }
                Ok(JournalRecord::Commit { version, step, witness, task_counter, seeds })
            }
            other => Err(format!("unknown record kind {other:?}")),
        }
    }
}

pub(crate) fn parse_hash(s: &str) -> Option<[u8; 32]> {
    if s.len() != 64 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let mut out = [0u8; 32];
    for (i, byte) in out.iter_mut().enumerate() {
        *byte = u8::from_str_radix(&s[2 * i..2 * i + 2], 16).ok()?;
    }
    Some(out)
}

fn parse_u64_hex(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Everything a resuming run needs, rebuilt from the last durable commit.
pub struct ResumePoint {
    /// Last journaled version.
    pub version: u64,
    /// Full-precision trainer state at `version`.
    pub state: TrainState,
    /// bf16 policy at `version` (reconstructed and witness-checked).
    pub policy: ParamSet,
    /// `D_version.hash` (trailing artifact hash), or `[0; 32]` at v0 —
    /// matches the live hub's `version_hash` convention.
    pub version_hash: [u8; 32],
    /// Task counter recorded at the last commit.
    pub task_counter: u64,
    /// Policy at `version - 1`, needed to regenerate the pending batch.
    /// `None` when `version == 0`.
    pub prev_policy: Option<ParamSet>,
    /// `version_hash` convention applied to `version - 1`.
    pub prev_hash: [u8; 32],
    /// Decoded checkpoints `D_1..D_version`, for reseeding the in-memory
    /// store (elastic bootstraps replay from it).
    pub chain: Vec<DeltaCheckpoint>,
}

/// Result of [`DurableStore::compact`].
#[derive(Debug, Clone, Copy)]
pub struct CompactStats {
    /// Highest version folded into the compacted object.
    pub upto: u64,
    /// Total encoded bytes of the individual chain artifacts D_1..D_upto.
    pub chain_bytes: u64,
    /// Encoded bytes of the folded artifact.
    pub compacted_bytes: u64,
}

/// A manifest entry, decoded from `refs/v{N}` / `refs/compact`.
#[derive(Debug, Clone)]
enum Manifest {
    Base { base: String, state: String },
    Delta { delta: String, delta_hash: [u8; 32], state: String },
    Compact { upto: u64, object: String },
}

/// Content-addressed durable store. See the module docs for the layout
/// and the crash-consistency protocol.
pub struct DurableStore {
    root: PathBuf,
    records: Vec<JournalRecord>,
}

impl DurableStore {
    /// Open (and create if absent) a persist directory, replaying and
    /// validating the journal. Verifies every journaled version's
    /// manifest and the content hash of every referenced object;
    /// truncates a torn final journal line.
    pub fn open(root: impl Into<PathBuf>) -> Result<DurableStore, RecoveryError> {
        let root = root.into();
        fs::create_dir_all(root.join("objects"))?;
        fs::create_dir_all(root.join("refs"))?;
        let mut store = DurableStore { root, records: Vec::new() };
        store.recover_journal()?;
        store.verify_chain()?;
        Ok(store)
    }

    /// Directory this store persists under.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// `true` when the journal holds no records (a brand-new run).
    pub fn is_fresh(&self) -> bool {
        self.records.is_empty()
    }

    /// Last journaled version, if any record exists.
    pub fn last_version(&self) -> Option<u64> {
        match self.records.last() {
            None => None,
            Some(JournalRecord::Genesis { .. }) => Some(0),
            Some(JournalRecord::Commit { version, .. }) => Some(*version),
        }
    }

    /// The replayed journal records, genesis first.
    pub fn records(&self) -> &[JournalRecord] {
        self.records.as_slice()
    }

    /// Journaled witness of `version`.
    pub fn witness(&self, version: u64) -> Result<[u8; 32], RecoveryError> {
        match self.records.get(version as usize) {
            Some(JournalRecord::Genesis { witness, .. }) => Ok(*witness),
            Some(JournalRecord::Commit { witness, .. }) => Ok(*witness),
            None => Err(RecoveryError::UnknownVersion { version }),
        }
    }

    fn journal_path(&self) -> PathBuf {
        self.root.join("journal.jsonl")
    }

    fn object_path(&self, id: &str) -> PathBuf {
        self.root.join("objects").join(format!("{id}.sprw"))
    }

    fn ref_path(&self, name: &str) -> PathBuf {
        self.root.join("refs").join(name)
    }

    /// Replay `journal.jsonl`. A parse failure on the final non-empty
    /// line is a torn append: the file is truncated back to the last
    /// good record. Any other malformation is a typed error.
    fn recover_journal(&mut self) -> Result<(), RecoveryError> {
        let path = self.journal_path();
        let raw = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        let text = String::from_utf8_lossy(&raw);
        let lines: Vec<&str> = text.split('\n').collect();
        let mut records = Vec::new();
        // Byte offset just past the last good line (incl. its newline).
        let mut good_bytes = 0usize;
        let mut torn = false;
        for (idx, line) in lines.iter().enumerate() {
            if line.trim().is_empty() {
                if lines[idx..].iter().all(|l| l.trim().is_empty()) {
                    break;
                }
                return Err(RecoveryError::CorruptJournal {
                    line: idx,
                    reason: "blank line before further records".into(),
                });
            }
            match Json::parse(line) {
                Ok(j) => match JournalRecord::from_json(&j) {
                    Ok(r) => {
                        records.push(r);
                        good_bytes += line.len() + 1;
                    }
                    // Schema-invalid but well-formed JSON is never a
                    // torn write; fail loudly wherever it sits.
                    Err(reason) => {
                        return Err(RecoveryError::CorruptJournal { line: idx, reason })
                    }
                },
                Err(reason) => {
                    // Unparseable content is a torn tail only if nothing
                    // but whitespace follows it.
                    if lines[idx + 1..].iter().all(|l| l.trim().is_empty()) {
                        torn = true;
                        break;
                    }
                    return Err(RecoveryError::CorruptJournal { line: idx, reason });
                }
            }
        }
        if torn {
            // Drop the torn tail on disk so the next append starts clean.
            let f = fs::OpenOptions::new().write(true).open(&path)?;
            f.set_len(good_bytes.min(raw.len()) as u64)?;
            f.sync_all()?;
        }
        // Validate ordering: genesis first, then contiguous commits.
        for (i, r) in records.iter().enumerate() {
            match (i, r) {
                (0, JournalRecord::Genesis { .. }) => {}
                (0, JournalRecord::Commit { .. }) => return Err(RecoveryError::MissingGenesis),
                (_, JournalRecord::Genesis { .. }) => {
                    return Err(RecoveryError::CorruptJournal {
                        line: i,
                        reason: "duplicate genesis record".into(),
                    })
                }
                (_, JournalRecord::Commit { version, .. }) => {
                    if *version != i as u64 {
                        return Err(RecoveryError::NonContiguous {
                            expected: i as u64,
                            found: *version,
                        });
                    }
                }
            }
        }
        self.records = records;
        Ok(())
    }

    /// Verify that every journaled version's manifest exists and every
    /// referenced object hashes to its content address.
    fn verify_chain(&self) -> Result<(), RecoveryError> {
        for version in 0..self.records.len() as u64 {
            let manifest = self.read_manifest(version)?;
            let ids: Vec<&String> = match &manifest {
                Manifest::Base { base, state } => vec![base, state],
                Manifest::Delta { delta, state, .. } => vec![delta, state],
                Manifest::Compact { .. } => {
                    return Err(RecoveryError::CorruptManifest {
                        version,
                        reason: "compact manifest stored under a version ref".into(),
                    })
                }
            };
            for id in ids {
                self.read_object(version, id)?;
            }
        }
        Ok(())
    }

    /// Read and content-verify an object.
    fn read_object(&self, version: u64, id: &str) -> Result<Vec<u8>, RecoveryError> {
        let path = self.object_path(id);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(RecoveryError::MissingObject { version, id: id.to_string() })
            }
            Err(e) => return Err(e.into()),
        };
        if hex(&Sha256::digest(&bytes)) != id {
            return Err(RecoveryError::ObjectHashMismatch { version, id: id.to_string() });
        }
        Ok(bytes)
    }

    /// Write `bytes` as a content-addressed object (tmp + fsync +
    /// rename). Writing an already-present address is a no-op, which is
    /// what makes post-crash recommits idempotent.
    fn put_object(&self, bytes: &[u8]) -> Result<String, RecoveryError> {
        let id = hex(&Sha256::digest(bytes));
        let path = self.object_path(&id);
        if path.exists() {
            return Ok(id);
        }
        let tmp = self.root.join("objects").join(format!(".{id}.tmp"));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        Ok(id)
    }

    fn write_ref(&self, name: &str, manifest: &Json) -> Result<(), RecoveryError> {
        let path = self.ref_path(name);
        let tmp = self.root.join("refs").join(format!(".{name}.tmp"));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(manifest.to_string().as_bytes())?;
            f.write_all(b"\n")?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn read_ref_json(&self, version: u64, name: &str) -> Result<Option<Json>, RecoveryError> {
        let raw = match fs::read_to_string(self.ref_path(name)) {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        match Json::parse(raw.trim()) {
            Ok(j) => Ok(Some(j)),
            Err(reason) => Err(RecoveryError::CorruptManifest { version, reason }),
        }
    }

    fn read_manifest(&self, version: u64) -> Result<Manifest, RecoveryError> {
        let name = format!("v{version}");
        let j = self
            .read_ref_json(version, &name)?
            .ok_or(RecoveryError::MissingManifest { version })?;
        Self::manifest_from_json(version, &j)
    }

    fn manifest_from_json(version: u64, j: &Json) -> Result<Manifest, RecoveryError> {
        let corrupt = |reason: &str| RecoveryError::CorruptManifest {
            version,
            reason: reason.to_string(),
        };
        let kind = j.get("kind").and_then(Json::as_str).ok_or_else(|| corrupt("missing kind"))?;
        match kind {
            "base" => {
                let base = j
                    .get("base")
                    .and_then(Json::as_str)
                    .ok_or_else(|| corrupt("missing base"))?
                    .to_string();
                let state = j
                    .get("state")
                    .and_then(Json::as_str)
                    .ok_or_else(|| corrupt("missing state"))?
                    .to_string();
                Ok(Manifest::Base { base, state })
            }
            "delta" => {
                let v = j
                    .get("version")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| corrupt("missing version"))?;
                if v != version {
                    return Err(corrupt(&format!("manifest says v{v}")));
                }
                let delta = j
                    .get("delta")
                    .and_then(Json::as_str)
                    .ok_or_else(|| corrupt("missing delta"))?
                    .to_string();
                let delta_hash = j
                    .get("delta_hash")
                    .and_then(Json::as_str)
                    .and_then(parse_hash)
                    .ok_or_else(|| corrupt("missing delta_hash"))?;
                let state = j
                    .get("state")
                    .and_then(Json::as_str)
                    .ok_or_else(|| corrupt("missing state"))?
                    .to_string();
                Ok(Manifest::Delta { delta, delta_hash, state })
            }
            "compact" => {
                let upto =
                    j.get("upto").and_then(Json::as_u64).ok_or_else(|| corrupt("missing upto"))?;
                let object = j
                    .get("object")
                    .and_then(Json::as_str)
                    .ok_or_else(|| corrupt("missing object"))?
                    .to_string();
                Ok(Manifest::Compact { upto, object })
            }
            other => Err(corrupt(&format!("unknown manifest kind {other:?}"))),
        }
    }

    /// Persist v0: base policy snapshot + trainer state + genesis
    /// journal record. Must be the first write into a fresh store.
    pub fn put_genesis(
        &mut self,
        layout: &ModelLayout,
        policy: &ParamSet,
        state: &TrainState,
        task_counter: u64,
        run_seed: u64,
    ) -> Result<(), RecoveryError> {
        let base_id = self.put_object(&policy.to_snapshot_bytes())?;
        let state_id = self.put_object(&encode_train_state(state))?;
        self.write_ref(
            "v0",
            &Json::obj()
                .set("kind", "base")
                .set("version", 0u64)
                .set("base", base_id)
                .set("state", state_id),
        )?;
        let record = JournalRecord::Genesis {
            witness: policy_witness(policy),
            task_counter,
            model_fp: layout.fingerprint(),
            run_seed,
        };
        self.append_record(record)
    }

    /// Seal a version's artifacts durably (delta object, trainer-state
    /// object, `refs/v{N}` manifest) WITHOUT journaling — the caller
    /// journals separately via [`DurableStore::append_commit`], and a
    /// crash between the two is recoverable.
    pub fn seal_version(
        &mut self,
        ckpt: &DeltaCheckpoint,
        state: &TrainState,
    ) -> Result<(), RecoveryError> {
        let delta_id = self.put_object(&ckpt.bytes)?;
        let state_id = self.put_object(&encode_train_state(state))?;
        self.write_ref(
            &format!("v{}", ckpt.version),
            &Json::obj()
                .set("kind", "delta")
                .set("version", ckpt.version)
                .set("delta", delta_id)
                .set("delta_hash", hex(&ckpt.hash))
                .set("state", state_id),
        )
    }

    /// Append the commit record for `version`. This is the commit point:
    /// only call it after [`DurableStore::seal_version`] returned Ok.
    pub fn append_commit(
        &mut self,
        version: u64,
        step: u64,
        witness: [u8; 32],
        task_counter: u64,
        seeds: Vec<SeedRecord>,
    ) -> Result<(), RecoveryError> {
        assert_eq!(
            version,
            self.records.len() as u64,
            "commit records must be appended in version order"
        );
        self.append_record(JournalRecord::Commit { version, step, witness, task_counter, seeds })
    }

    fn append_record(&mut self, record: JournalRecord) -> Result<(), RecoveryError> {
        let path = self.journal_path();
        let mut f = fs::OpenOptions::new().create(true).append(true).open(&path)?;
        // Heal a good-but-unterminated final line (tail truncation can
        // leave one when the last good record had no trailing newline).
        let len = f.seek(SeekFrom::End(0))?;
        if len > 0 {
            let mut last = [0u8; 1];
            let mut rf = fs::File::open(&path)?;
            rf.seek(SeekFrom::End(-1))?;
            rf.read_exact(&mut last)?;
            if last[0] != b'\n' {
                f.write_all(b"\n")?;
            }
        }
        let mut line = record.to_json().to_string();
        line.push('\n');
        f.write_all(line.as_bytes())?;
        f.sync_all()?;
        self.records.push(record);
        Ok(())
    }

    /// Decode the delta checkpoint committed at `version` (>= 1),
    /// verifying content hash and artifact integrity.
    pub fn delta(&self, version: u64) -> Result<DeltaCheckpoint, RecoveryError> {
        if version == 0 || version as usize >= self.records.len() {
            return Err(RecoveryError::UnknownVersion { version });
        }
        let manifest = self.read_manifest(version)?;
        let (delta_id, delta_hash) = match manifest {
            Manifest::Delta { delta, delta_hash, .. } => (delta, delta_hash),
            _ => {
                return Err(RecoveryError::CorruptManifest {
                    version,
                    reason: "expected a delta manifest".into(),
                })
            }
        };
        let bytes = self.read_object(version, &delta_id)?;
        let ckpt = DeltaCheckpoint::from_bytes(bytes).map_err(|error| {
            RecoveryError::CorruptArtifact { path: self.object_path(&delta_id), error }
        })?;
        if ckpt.hash != delta_hash {
            return Err(RecoveryError::CorruptManifest {
                version,
                reason: "manifest delta_hash disagrees with the artifact".into(),
            });
        }
        if ckpt.version != version {
            return Err(RecoveryError::CorruptManifest {
                version,
                reason: format!("artifact encodes v{}", ckpt.version),
            });
        }
        Ok(ckpt)
    }

    /// Decode the trainer state persisted at `version`.
    pub fn train_state(&self, version: u64) -> Result<TrainState, RecoveryError> {
        if version as usize >= self.records.len() {
            return Err(RecoveryError::UnknownVersion { version });
        }
        let state_id = match self.read_manifest(version)? {
            Manifest::Base { state, .. } | Manifest::Delta { state, .. } => state,
            Manifest::Compact { .. } => {
                return Err(RecoveryError::CorruptManifest {
                    version,
                    reason: "compact manifest stored under a version ref".into(),
                })
            }
        };
        let bytes = self.read_object(version, &state_id)?;
        decode_train_state(&bytes).map_err(|reason| RecoveryError::CorruptManifest {
            version,
            reason: format!("train state object: {reason}"),
        })
    }

    /// Decode the v0 base policy snapshot.
    pub fn base_policy(&self, layout: &ModelLayout) -> Result<ParamSet, RecoveryError> {
        if self.records.is_empty() {
            return Err(RecoveryError::UnknownVersion { version: 0 });
        }
        let base_id = match self.read_manifest(0)? {
            Manifest::Base { base, .. } => base,
            _ => {
                return Err(RecoveryError::CorruptManifest {
                    version: 0,
                    reason: "v0 manifest is not a base snapshot".into(),
                })
            }
        };
        let bytes = self.read_object(0, &base_id)?;
        ParamSet::from_snapshot_bytes(layout, &bytes)
            .map_err(|reason| RecoveryError::CorruptManifest { version: 0, reason })
    }

    /// Materialize the policy at `version` by replaying the delta chain
    /// over the base snapshot (using the compacted object when one
    /// covers a prefix), then verify it against the journaled witness.
    pub fn reconstruct(
        &self,
        layout: &ModelLayout,
        version: u64,
    ) -> Result<ParamSet, RecoveryError> {
        if version as usize >= self.records.len() {
            return Err(RecoveryError::UnknownVersion { version });
        }
        let mut policy = self.base_policy(layout)?;
        let mut next = 1u64;
        if let Some((upto, ckpt)) = self.compacted()? {
            if upto <= version {
                let delta = ckpt.open().map_err(|error| RecoveryError::CorruptArtifact {
                    path: self.ref_path("compact"),
                    error,
                })?;
                crate::delta::apply_delta(&mut policy, &delta);
                next = upto + 1;
            }
        }
        for v in next..=version {
            let ckpt = self.delta(v)?;
            let delta = ckpt.open().map_err(|error| RecoveryError::CorruptArtifact {
                path: self.object_path(&hex(&Sha256::digest(&ckpt.bytes))),
                error,
            })?;
            crate::delta::apply_delta(&mut policy, &delta);
        }
        let witness = self.witness(version)?;
        if policy_witness(&policy) != witness {
            return Err(RecoveryError::WitnessMismatch { version });
        }
        Ok(policy)
    }

    /// The compacted-chain checkpoint, when `refs/compact` exists.
    /// Returns the highest version it covers and the decoded artifact.
    pub fn compacted(&self) -> Result<Option<(u64, DeltaCheckpoint)>, RecoveryError> {
        let j = match self.read_ref_json(0, "compact")? {
            Some(j) => j,
            None => return Ok(None),
        };
        let (upto, object) = match Self::manifest_from_json(0, &j)? {
            Manifest::Compact { upto, object } => (upto, object),
            _ => {
                return Err(RecoveryError::CorruptManifest {
                    version: 0,
                    reason: "refs/compact is not a compact manifest".into(),
                })
            }
        };
        let bytes = self.read_object(upto, &object)?;
        let ckpt = DeltaCheckpoint::from_bytes(bytes).map_err(|error| {
            RecoveryError::CorruptArtifact { path: self.object_path(&object), error }
        })?;
        Ok(Some((upto, ckpt)))
    }

    /// Fold `D_1..D_upto` into one object and point `refs/compact` at
    /// it. Verifies the folded chain reproduces the journaled witness
    /// before publishing the ref. Defaults to the last journaled
    /// version when `upto` is `None`.
    pub fn compact(
        &mut self,
        layout: &ModelLayout,
        upto: Option<u64>,
    ) -> Result<CompactStats, RecoveryError> {
        let last = self.last_version().ok_or(RecoveryError::UnknownVersion { version: 0 })?;
        let upto = upto.unwrap_or(last);
        if upto == 0 || upto > last {
            return Err(RecoveryError::UnknownVersion { version: upto });
        }
        let mut chain_bytes = 0u64;
        let mut chain = Vec::with_capacity(upto as usize);
        for v in 1..=upto {
            let ckpt = self.delta(v)?;
            chain_bytes += ckpt.bytes.len() as u64;
            let delta = ckpt.open().map_err(|error| RecoveryError::CorruptArtifact {
                path: self.object_path(&hex(&Sha256::digest(&ckpt.bytes))),
                error,
            })?;
            chain.push(delta);
        }
        let merged = merge_chain(&chain)?;
        let folded = DeltaCheckpoint::seal(&merged);
        // Verify the fold against the journaled witness before any ref
        // becomes visible: base + merged must equal base + D_1..D_upto.
        let mut check = self.base_policy(layout)?;
        let reopened = folded.open().map_err(|error| RecoveryError::CorruptArtifact {
            path: self.ref_path("compact"),
            error,
        })?;
        crate::delta::apply_delta(&mut check, &reopened);
        if policy_witness(&check) != self.witness(upto)? {
            return Err(RecoveryError::WitnessMismatch { version: upto });
        }
        let compacted_bytes = folded.bytes.len() as u64;
        let object = self.put_object(&folded.bytes)?;
        self.write_ref(
            "compact",
            &Json::obj().set("kind", "compact").set("upto", upto).set("object", object),
        )?;
        Ok(CompactStats { upto, chain_bytes, compacted_bytes })
    }

    /// Rebuild everything a resuming run needs from the last journaled
    /// commit, checking the persisted identity against the resuming
    /// config. `[0; 32]` stands in for the genesis hash, matching the
    /// live hub.
    pub fn resume_point(
        &self,
        layout: &ModelLayout,
        run_seed: u64,
    ) -> Result<ResumePoint, RecoveryError> {
        let (genesis_fp, genesis_seed) = match self.records.first() {
            Some(JournalRecord::Genesis { model_fp, run_seed, .. }) => (*model_fp, *run_seed),
            _ => return Err(RecoveryError::MissingGenesis),
        };
        if genesis_fp != layout.fingerprint() {
            return Err(RecoveryError::ConfigMismatch { field: "model_fp" });
        }
        if genesis_seed != run_seed {
            return Err(RecoveryError::ConfigMismatch { field: "run_seed" });
        }
        let version = self.last_version().unwrap();
        let task_counter = match &self.records[version as usize] {
            JournalRecord::Genesis { task_counter, .. } => *task_counter,
            JournalRecord::Commit { task_counter, .. } => *task_counter,
        };
        let state = self.train_state(version)?;
        let policy = self.reconstruct(layout, version)?;
        let mut chain = Vec::with_capacity(version as usize);
        for v in 1..=version {
            chain.push(self.delta(v)?);
        }
        let version_hash =
            if version == 0 { [0u8; 32] } else { chain[version as usize - 1].hash };
        let (prev_policy, prev_hash) = if version == 0 {
            (None, [0u8; 32])
        } else {
            let prev = self.reconstruct(layout, version - 1)?;
            let ph = if version == 1 { [0u8; 32] } else { chain[version as usize - 2].hash };
            (Some(prev), ph)
        };
        Ok(ResumePoint {
            version,
            state,
            policy,
            version_hash,
            task_counter,
            prev_policy,
            prev_hash,
            chain,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, CheckpointStore};
    use crate::util::Rng;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sprw-durable-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn layout() -> ModelLayout {
        ModelLayout::transformer("store-test", 64, 16, 2, 32)
    }

    /// Build a store with a genesis and `n` committed versions; returns
    /// (store, layout, per-version policies p_0..p_n).
    fn seeded_store(dir: &Path, n: u64) -> (DurableStore, ModelLayout, Vec<ParamSet>) {
        let l = layout();
        let mut rng = Rng::new(1);
        let mut policies = vec![ParamSet::random(&l, 0.02, &mut rng)];
        let state = TrainState::init(&l, &mut rng);
        let mut store = DurableStore::open(dir).unwrap();
        store.put_genesis(&l, &policies[0], &state, 0, 42).unwrap();
        for v in 1..=n {
            let mut next = policies[v as usize - 1].clone();
            // Perturb a few elements so each delta is small and sparse.
            for _ in 0..8 {
                let t = (rng.next_u64() % l.tensors.len() as u64) as usize;
                let len = next.tensors[t].len();
                let i = (rng.next_u64() % len as u64) as usize;
                next.tensors[t][i] = Bf16::from_f32(rng.normal() as f32);
            }
            let delta =
                extract_delta(&l, &policies[v as usize - 1], &next, v - 1, v, ApplyMode::Assign);
            let ckpt = DeltaCheckpoint::seal(&delta);
            store.seal_version(&ckpt, &state).unwrap();
            store
                .append_commit(
                    v,
                    v - 1,
                    policy_witness(&next),
                    v * 10,
                    vec![SeedRecord { actor: 0, seed: v }],
                )
                .unwrap();
            policies.push(next);
        }
        (store, l, policies)
    }

    #[test]
    fn fresh_open_round_trip() {
        let dir = test_dir("fresh");
        let (store, l, policies) = seeded_store(&dir, 4);
        assert_eq!(store.last_version(), Some(4));
        // Reopen from disk and verify recovery sees the same chain.
        drop(store);
        let store = DurableStore::open(&dir).unwrap();
        assert_eq!(store.last_version(), Some(4));
        for v in 0..=4u64 {
            let p = store.reconstruct(&l, v).unwrap();
            assert_eq!(
                policy_witness(&p),
                policy_witness(&policies[v as usize]),
                "v{v} reconstruction differs"
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_journal_tail_is_truncated() {
        let dir = test_dir("torn");
        let (store, _, _) = seeded_store(&dir, 3);
        drop(store);
        // Simulate a crash mid-append: add half a record.
        let path = dir.join("journal.jsonl");
        let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"kind\":\"commit\",\"vers").unwrap();
        drop(f);
        let store = DurableStore::open(&dir).unwrap();
        assert_eq!(store.last_version(), Some(3), "torn tail must roll back to v3");
        // The file itself must have been healed.
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(text.lines().count(), 4);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn interior_corruption_is_typed() {
        let dir = test_dir("interior");
        let (store, _, _) = seeded_store(&dir, 3);
        drop(store);
        let path = dir.join("journal.jsonl");
        let text = fs::read_to_string(&path).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        lines[1] = "{\"kind\":\"commit\",\"vers"; // corrupt a middle line
        fs::write(&path, format!("{}\n", lines.join("\n"))).unwrap();
        let err = DurableStore::open(&dir).err().expect("open must fail");
        match err {
            RecoveryError::CorruptJournal { line, .. } => assert_eq!(line, 1),
            other => panic!("expected CorruptJournal, got {other}"),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_and_corrupt_objects_are_typed() {
        let dir = test_dir("objects");
        let (store, _, _) = seeded_store(&dir, 3);
        // Find v2's delta object via its manifest, then delete it.
        let manifest = fs::read_to_string(dir.join("refs/v2")).unwrap();
        let j = Json::parse(manifest.trim()).unwrap();
        let id = j.get("delta").and_then(Json::as_str).unwrap().to_string();
        drop(store);
        let obj = dir.join("objects").join(format!("{id}.sprw"));
        let bytes = fs::read(&obj).unwrap();
        fs::remove_file(&obj).unwrap();
        match DurableStore::open(&dir).err().expect("open must fail") {
            RecoveryError::MissingObject { version, id: got } => {
                assert_eq!(version, 2);
                assert_eq!(got, id);
            }
            other => panic!("expected MissingObject, got {other}"),
        }
        // Restore it corrupted: content no longer matches the address.
        let mut bad = bytes.clone();
        bad[10] ^= 0xff;
        fs::write(&obj, &bad).unwrap();
        match DurableStore::open(&dir).err().expect("open must fail") {
            RecoveryError::ObjectHashMismatch { version, .. } => assert_eq!(version, 2),
            other => panic!("expected ObjectHashMismatch, got {other}"),
        }
        // Restore the original bytes: recovery succeeds again.
        fs::write(&obj, &bytes).unwrap();
        assert!(DurableStore::open(&dir).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_is_typed() {
        let dir = test_dir("manifest");
        let (store, _, _) = seeded_store(&dir, 2);
        drop(store);
        fs::remove_file(dir.join("refs/v1")).unwrap();
        match DurableStore::open(&dir).err().expect("open must fail") {
            RecoveryError::MissingManifest { version } => assert_eq!(version, 1),
            other => panic!("expected MissingManifest, got {other}"),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sealed_but_unjournaled_version_is_invisible() {
        let dir = test_dir("unjournaled");
        let (store, l, policies) = seeded_store(&dir, 3);
        drop(store);
        // Delete the last journal line: v3's objects + manifest remain
        // durable, but the commit record is gone — exactly the state a
        // crash between seal_version and append_commit leaves behind.
        let path = dir.join("journal.jsonl");
        let text = fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        fs::write(&path, format!("{}\n", lines[..lines.len() - 1].join("\n"))).unwrap();
        let store = DurableStore::open(&dir).unwrap();
        assert_eq!(store.last_version(), Some(2));
        assert!(matches!(store.delta(3), Err(RecoveryError::UnknownVersion { version: 3 })));
        let p2 = store.reconstruct(&l, 2).unwrap();
        assert_eq!(policy_witness(&p2), policy_witness(&policies[2]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn recommit_after_crash_is_idempotent() {
        let dir = test_dir("recommit");
        let (store, l, policies) = seeded_store(&dir, 3);
        drop(store);
        let path = dir.join("journal.jsonl");
        let before = fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = before.lines().collect();
        fs::write(&path, format!("{}\n", lines[..lines.len() - 1].join("\n"))).unwrap();
        let mut store = DurableStore::open(&dir).unwrap();
        // Recommit v3 with identical content: objects dedupe, the
        // manifest rewrite is byte-identical, the journal heals.
        let delta = extract_delta(&l, &policies[2], &policies[3], 2, 3, ApplyMode::Assign);
        let ckpt = DeltaCheckpoint::seal(&delta);
        let mut rng = Rng::new(1);
        let state = TrainState::init(&l, &mut rng);
        store.seal_version(&ckpt, &state).unwrap();
        store
            .append_commit(
                3,
                2,
                policy_witness(&policies[3]),
                30,
                vec![SeedRecord { actor: 0, seed: 3 }],
            )
            .unwrap();
        assert_eq!(store.last_version(), Some(3));
        assert_eq!(fs::read_to_string(&path).unwrap(), before);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_is_bit_exact_and_layers_with_replay() {
        let dir = test_dir("compact");
        let (mut store, l, policies) = seeded_store(&dir, 5);
        let stats = store.compact(&l, None).unwrap();
        assert_eq!(stats.upto, 5);
        assert!(stats.compacted_bytes > 0);
        // Reconstruct through the compacted object; must still match.
        let p5 = store.reconstruct(&l, 5).unwrap();
        assert_eq!(policy_witness(&p5), policy_witness(&policies[5]));
        // A partial compaction still lets later versions replay on top.
        let stats = store.compact(&l, Some(3)).unwrap();
        assert_eq!(stats.upto, 3);
        let p5b = store.reconstruct(&l, 5).unwrap();
        assert_eq!(policy_witness(&p5b), policy_witness(&policies[5]));
        // Versions below the compaction horizon replay per-delta.
        let p2 = store.reconstruct(&l, 2).unwrap();
        assert_eq!(policy_witness(&p2), policy_witness(&policies[2]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_chain_rejects_bad_chains() {
        let l = layout();
        let mut rng = Rng::new(9);
        let a = ParamSet::random(&l, 0.02, &mut rng);
        let mut b = a.clone();
        b.tensors[0][0] = Bf16::from_f32(0.25);
        let mut c = b.clone();
        c.tensors[0][1] = Bf16::from_f32(0.75);
        let d1 = extract_delta(&l, &a, &b, 0, 1, ApplyMode::Assign);
        let d2 = extract_delta(&l, &b, &c, 1, 2, ApplyMode::Assign);
        assert_eq!(merge_chain(&[]), Err(MergeError::Empty));
        let mut add = d1.clone();
        add.mode = ApplyMode::Add;
        assert_eq!(merge_chain(&[add]), Err(MergeError::AddMode { version: 1 }));
        let gap = extract_delta(&l, &b, &c, 5, 6, ApplyMode::Assign);
        assert_eq!(
            merge_chain(&[d1.clone(), gap]),
            Err(MergeError::NonContiguous { expected: 1, found: 5 })
        );
        let mut alien = d2.clone();
        alien.model_fp ^= 1;
        assert_eq!(merge_chain(&[d1.clone(), alien]), Err(MergeError::ModelMismatch));
        let merged = merge_chain(&[d1, d2]).unwrap();
        let mut p = a.clone();
        crate::delta::apply_delta(&mut p, &merged);
        assert_eq!(policy_witness(&p), policy_witness(&c));
    }

    #[test]
    fn train_state_codec_round_trips() {
        let l = layout();
        let mut rng = Rng::new(7);
        let mut state = TrainState::init(&l, &mut rng);
        for group in [&mut state.m, &mut state.v] {
            for tensor in group.iter_mut() {
                for x in tensor.iter_mut() {
                    *x = rng.normal() as f32;
                }
            }
        }
        state.step = 1234;
        let bytes = encode_train_state(&state);
        let back = decode_train_state(&bytes).unwrap();
        assert_eq!(back.step, 1234);
        assert_eq!(back.masters, state.masters);
        assert_eq!(back.m, state.m);
        assert_eq!(back.v, state.v);
        assert!(decode_train_state(&bytes[..bytes.len() - 1]).is_err());
        assert!(decode_train_state(b"XXXX").is_err());
    }

    #[test]
    fn resume_point_checks_identity() {
        let dir = test_dir("resume-point");
        let (store, l, policies) = seeded_store(&dir, 3);
        let rp = store.resume_point(&l, 42).unwrap();
        assert_eq!(rp.version, 3);
        assert_eq!(rp.task_counter, 30);
        assert_eq!(policy_witness(&rp.policy), policy_witness(&policies[3]));
        assert_eq!(
            policy_witness(rp.prev_policy.as_ref().unwrap()),
            policy_witness(&policies[2])
        );
        assert_eq!(rp.chain.len(), 3);
        assert_eq!(rp.version_hash, rp.chain[2].hash);
        assert_eq!(rp.prev_hash, rp.chain[1].hash);
        // Wrong seed and wrong model both refuse to resume.
        assert!(matches!(
            store.resume_point(&l, 43),
            Err(RecoveryError::ConfigMismatch { field: "run_seed" })
        ));
        let other = ModelLayout::transformer("other-model", 64, 16, 2, 32);
        assert!(matches!(
            store.resume_point(&other, 42),
            Err(RecoveryError::ConfigMismatch { field: "model_fp" })
        ));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn chain_feeds_checkpoint_store() {
        // The resume path seeds the hub's in-memory CheckpointStore from
        // ResumePoint::chain; make sure the pieces fit together.
        let dir = test_dir("chain-seed");
        let (store, l, _) = seeded_store(&dir, 3);
        let rp = store.resume_point(&l, 42).unwrap();
        let mut mem = CheckpointStore::in_memory();
        for ckpt in rp.chain {
            mem.put(ckpt).unwrap();
        }
        assert_eq!(mem.latest_version(), Some(3));
        let _ = fs::remove_dir_all(&dir);
    }
}
