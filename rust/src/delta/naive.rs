//! Naive fixed-width sparse encoding — the baseline of Figure 10.
//!
//! Each nonzero is an (index, value) pair with an int32 index (int64 when
//! the tensor exceeds 2^32 elements) and a bf16 value, so position metadata
//! is two-thirds (or more) of the payload. SparrowRL's varint format beats
//! this by 30–50% (paper: 414 MB -> 202 MB for Qwen3-8B).

use super::{SparseDelta, TensorDelta};
use crate::delta::ModelLayout;
use crate::util::Bf16;

/// Bytes per index entry for a tensor of `numel` elements.
pub fn index_width(numel: u64) -> usize {
    if numel <= u32::MAX as u64 {
        4
    } else {
        8
    }
}

/// Exact encoded size of `d` under the naive scheme (header-free payload,
/// for apples-to-apples payload comparisons).
pub fn naive_payload_len(d: &SparseDelta, layout: &ModelLayout) -> usize {
    d.tensors
        .iter()
        .map(|t| {
            let w = index_width(layout.tensors[t.tensor as usize].numel());
            t.idx.len() * (w + 2)
        })
        .sum()
}

/// Encode with fixed-width indices (per-tensor sections, no compression).
pub fn encode_naive(d: &SparseDelta, layout: &ModelLayout) -> Vec<u8> {
    let mut out = Vec::with_capacity(naive_payload_len(d, layout) + d.tensors.len() * 16 + 16);
    out.extend_from_slice(&(d.tensors.len() as u32).to_le_bytes());
    for t in &d.tensors {
        let w = index_width(layout.tensors[t.tensor as usize].numel());
        out.extend_from_slice(&t.tensor.to_le_bytes());
        out.extend_from_slice(&(t.nnz()).to_le_bytes());
        out.push(w as u8);
        for &i in &t.idx {
            match w {
                4 => out.extend_from_slice(&(i as u32).to_le_bytes()),
                _ => out.extend_from_slice(&i.to_le_bytes()),
            }
        }
        for v in &t.vals {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    out
}

/// Decode the naive format (test/bench support; version/mode metadata is
/// carried out-of-band by the caller in baseline experiments).
pub fn decode_naive(bytes: &[u8]) -> Option<Vec<TensorDelta>> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Option<&[u8]> {
        let s = bytes.get(*pos..*pos + n)?;
        *pos += n;
        Some(s)
    };
    let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into().ok()?) as usize;
    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let tensor = u32::from_le_bytes(take(&mut pos, 4)?.try_into().ok()?);
        let nnz = u64::from_le_bytes(take(&mut pos, 8)?.try_into().ok()?) as usize;
        let w = *take(&mut pos, 1)?.first()? as usize;
        if w != 4 && w != 8 {
            return None;
        }
        let mut idx = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let b = take(&mut pos, w)?;
            idx.push(match w {
                4 => u32::from_le_bytes(b.try_into().ok()?) as u64,
                _ => u64::from_le_bytes(b.try_into().ok()?),
            });
        }
        let mut vals = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let b = take(&mut pos, 2)?;
            vals.push(Bf16::from_bits(u16::from_le_bytes([b[0], b[1]])));
        }
        tensors.push(TensorDelta { tensor, idx, vals });
    }
    (pos == bytes.len()).then_some(tensors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{ApplyMode, ModelLayout};
    use crate::util::{prop, Rng};

    fn delta_with(layout: &ModelLayout, density: f64, seed: u64) -> SparseDelta {
        let mut rng = Rng::new(seed);
        let tensors = layout
            .tensors
            .iter()
            .enumerate()
            .map(|(tid, spec)| {
                let n = spec.numel();
                let k = ((n as f64 * density) as usize).max(1).min(n as usize);
                let idx = prop::sparse_indices(&mut rng, n, k);
                let vals = (0..k).map(|_| Bf16::from_f32(rng.normal() as f32)).collect();
                TensorDelta { tensor: tid as u32, idx, vals }
            })
            .collect();
        SparseDelta {
            version: 1,
            base_version: 0,
            model_fp: layout.fingerprint(),
            mode: ApplyMode::Assign,
            tensors,
        }
    }

    #[test]
    fn naive_round_trip() {
        let l = ModelLayout::transformer("t", 128, 32, 2, 64);
        let d = delta_with(&l, 0.01, 5);
        let bytes = encode_naive(&d, &l);
        let back = decode_naive(&bytes).unwrap();
        assert_eq!(back, d.tensors);
    }

    #[test]
    fn varint_beats_naive_by_30_to_60_percent_at_1pct() {
        // The Figure 10 claim: varint indexing cuts total payload vs
        // naive int32 encoding (414 MB -> 202 MB is ~51%).
        let l = ModelLayout::transformer("t", 2048, 256, 4, 1024);
        let d = delta_with(&l, 0.01, 6);
        let naive = encode_naive(&d, &l).len() as f64;
        let varint = super::super::encode_delta(&d).len() as f64;
        let cut = 1.0 - varint / naive;
        assert!(
            (0.30..0.60).contains(&cut),
            "payload cut {:.1}% outside the paper's 30-50% band (naive={naive}, varint={varint})",
            cut * 100.0
        );
    }

    #[test]
    fn index_width_switches_at_u32_boundary() {
        assert_eq!(index_width(100), 4);
        assert_eq!(index_width(u32::MAX as u64), 4);
        assert_eq!(index_width(u32::MAX as u64 + 1), 8);
    }
}
