//! Streaming zero-copy delta pipeline: fused extract → encode → segment.
//!
//! The seed pipeline materialized three full copies of every checkpoint:
//! `extract_delta` built `Vec<u64>`/`Vec<Bf16>` per tensor, `encode_delta`
//! re-walked that into one contiguous byte buffer, and `split_into_segments`
//! copied the buffer a third time into frames — so the first byte could not
//! reach the wire until the entire dense scan (~5 s for a 16 GB model)
//! finished. This module fuses all three passes (paper §5.2, "pipeline
//! delta extraction with multi-stream transmission"):
//!
//! * [`DeltaStreamEncoder`] scans each tensor chunk-by-chunk with the same
//!   word-at-a-time bit compare as `extract.rs`, gap-varint-encodes indices
//!   and appends raw bf16 values directly into per-tensor section buffers,
//!   folds every emitted byte into an incremental SHA-256, and yields
//!   wire-ready [`Segment`] frames as soon as they fill — transmission of
//!   tensor 0 overlaps extraction of tensor N. A multi-threaded variant
//!   ([`DeltaStreamEncoder::encode_parallel`]) fans per-tensor shard
//!   workers over a bounded queue and re-serializes sections in layout
//!   order on the emitting thread, replacing `extract_delta_parallel`'s
//!   collect-then-merge.
//! * [`DeltaStreamDecoder`] is the actor-side dual: it parses the canonical
//!   byte stream incrementally as segments arrive (tolerating reordering
//!   and duplicates), freeing each segment payload as soon as it is
//!   consumed, so staging never holds the full checkpoint byte buffer the
//!   way `transport/reassembly.rs` does. [`DeltaStreamApplier`] goes one
//!   step further and scatter-assigns each completed tensor section into
//!   actor-resident parameters immediately, keeping an undo log so a
//!   trailer-hash mismatch rolls the parameters back bit-exactly.
//!
//! # Frame format
//!
//! The byte stream is exactly `encode_delta`'s canonical format (see
//! `encode.rs`: 36-byte header, self-delimiting sections, `SECTION_END`
//! terminator, SHA-256 trailer) — the two paths are bit-identical by
//! construction and asserted by tests below. Frames are `Segment`s of
//! `segment_bytes` payload; every frame except the last carries
//! `total == TOTAL_UNKNOWN (0)` because a single-pass encoder only learns
//! the stream length at the end; the final frame carries the true segment
//! count. `Reassembler` and the stream decoder both grow their state on
//! unknown-total segments and bind the geometry when the final frame
//! arrives, so legacy fixed-geometry streams and streaming frames share
//! one receive path.
//!
//! # Buffer-pool lifecycle
//!
//! The encoder owns two reusable section buffers (`idx_buf`, `val_buf`)
//! whose high-water mark is one tensor's encoded section, plus a
//! [`FramePool`] of frame buffers: a frame is handed to the sink inside a
//! `Segment`, and transports that finish writing a frame can `recycle()`
//! it back into the pool, making the steady state allocation-free. The
//! decoder's working set is one partially parsed field (< 32 bytes) plus
//! the current section — never the whole checkpoint.
//!
//! # Overlap model
//!
//! Section granularity is the tensor: the wire format stores a section's
//! `nnz` and `idx_bytes` *before* its payload, so a section is emitted
//! when its tensor's scan completes, and frames flow as soon as
//! `segment_bytes` of encoded stream exist. With the fused transformer
//! layout (7+ tensors, the large MLP projections dominating), the first
//! frames ship while >80% of the model is still unscanned; the pipelining
//! test below asserts the first segment is emitted before the last tensor
//! is reached. The simulator (`sim/compute.rs::stream_emit_bps`) models
//! the source rate of this pipeline as payload produced uniformly over a
//! single fused scan at `STREAM_ENCODE_BPS` (~2x the seed's two-pass
//! effective rate; see `rust/benches/encoding.rs` / BENCH_encoding.json
//! for the measured scan/encode GB/s on the build machine).

use super::encode::{self, SECTION_END};
use super::extract::scan_changed;
use super::varint;
use super::{ApplyMode, ModelLayout, ParamSet, SparseDelta, TensorDelta};
use crate::transport::segment::{Segment, DEFAULT_SEGMENT_BYTES, TOTAL_UNKNOWN};
use crate::util::Bf16;
use sha2::{Digest, Sha256};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

/// Tuning knobs for the streaming encoder.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Payload bytes per emitted segment (must match the transfer plan).
    pub segment_bytes: usize,
    /// Elements compared per scan chunk (rounded down to a multiple of 4
    /// so the word-at-a-time path stays hot; the tail chunk may be odd).
    pub chunk_elems: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { segment_bytes: DEFAULT_SEGMENT_BYTES, chunk_elems: 1 << 16 }
    }
}

/// What one streaming encode produced.
#[derive(Clone, Debug)]
pub struct StreamStats {
    /// Changed elements across all tensors.
    pub nnz: u64,
    /// Total encoded stream length (header + sections + terminator + hash).
    pub payload_bytes: u64,
    /// Segments emitted.
    pub segments: u32,
    /// Tensors with at least one changed element.
    pub changed_tensors: u32,
    /// The stream's SHA-256 trailer (the checkpoint integrity hash).
    pub hash: [u8; 32],
    /// Tensor index that was being scanned when the first (non-final)
    /// segment left the encoder — `Some(t)` with `t < n_tensors - 1`
    /// demonstrates extraction/transmission overlap; `None` means the
    /// stream fit in a single segment (no overlap possible).
    pub first_segment_tensor: Option<u32>,
    /// Wall time of the fused scan+encode pass.
    pub scan_s: f64,
}

/// Recycling pool for frame buffers. Transports hand written-out frames
/// back via [`FramePool::recycle`]; the encoder draws from the pool before
/// allocating. Clones share one pool.
#[derive(Clone, Default)]
pub struct FramePool(Rc<RefCell<Vec<Vec<u8>>>>);

impl FramePool {
    pub fn new() -> FramePool {
        FramePool::default()
    }

    /// Return a frame buffer to the pool for reuse.
    pub fn recycle(&self, mut buf: Vec<u8>) {
        buf.clear();
        self.0.borrow_mut().push(buf);
    }

    /// Buffers currently pooled.
    pub fn len(&self) -> usize {
        self.0.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.borrow().is_empty()
    }

    fn take(&self, cap: usize) -> Vec<u8> {
        match self.0.borrow_mut().pop() {
            Some(b) => b,
            None => Vec::with_capacity(cap),
        }
    }
}

/// One completed per-tensor section produced by a shard worker.
struct SectionMsg {
    nnz: u64,
    idx: Vec<u8>,
    vals: Vec<u8>,
}

/// Frame assembly state shared by the serial and parallel encoders.
struct Emitter<'p, F: FnMut(Segment)> {
    version: u64,
    segment_bytes: usize,
    frame: Vec<u8>,
    seq: u32,
    hasher: Sha256,
    sink: F,
    pool: &'p FramePool,
    bytes: u64,
    cur_tensor: u32,
    first_segment_tensor: Option<u32>,
}

impl<'p, F: FnMut(Segment)> Emitter<'p, F> {
    fn new(version: u64, segment_bytes: usize, pool: &'p FramePool, sink: F) -> Self {
        Emitter {
            version,
            segment_bytes,
            frame: pool.take(segment_bytes),
            seq: 0,
            hasher: Sha256::new(),
            sink,
            pool,
            bytes: 0,
            cur_tensor: 0,
            first_segment_tensor: None,
        }
    }

    /// Append stream bytes, folding them into the running hash.
    fn emit(&mut self, bytes: &[u8]) {
        self.hasher.update(bytes);
        self.emit_unhashed(bytes);
    }

    /// Append stream bytes without hashing (the trailer itself).
    fn emit_unhashed(&mut self, mut bytes: &[u8]) {
        self.bytes += bytes.len() as u64;
        while !bytes.is_empty() {
            // A full frame is only flushed once more bytes arrive, so the
            // final flush (which carries the true total) is never preceded
            // by an unmarked full frame.
            if self.frame.len() == self.segment_bytes {
                self.flush(false);
            }
            let take = (self.segment_bytes - self.frame.len()).min(bytes.len());
            self.frame.extend_from_slice(&bytes[..take]);
            bytes = &bytes[take..];
        }
    }

    fn flush(&mut self, last: bool) {
        let payload = std::mem::replace(&mut self.frame, self.pool.take(self.segment_bytes));
        if !last && self.seq == 0 {
            self.first_segment_tensor = Some(self.cur_tensor);
        }
        let total = if last { self.seq + 1 } else { TOTAL_UNKNOWN };
        let seg = Segment { version: self.version, seq: self.seq, total, payload };
        self.seq += 1;
        (self.sink)(seg);
    }

    fn emit_section(&mut self, tensor: u32, nnz: u64, idx: &[u8], vals: &[u8]) {
        let mut head = [0u8; encode::SECTION_HEADER_LEN];
        head[0..4].copy_from_slice(&tensor.to_le_bytes());
        head[4..12].copy_from_slice(&nnz.to_le_bytes());
        head[12..20].copy_from_slice(&(idx.len() as u64).to_le_bytes());
        self.emit(&head);
        self.emit(idx);
        self.emit(vals);
    }

    /// Terminator + hash trailer + final frame. Returns (hash, segments).
    fn finish(mut self) -> ([u8; 32], u32, u64, Option<u32>) {
        self.emit(&SECTION_END.to_le_bytes());
        let hasher = std::mem::replace(&mut self.hasher, Sha256::new());
        let hash = hasher.finalize();
        self.emit_unhashed(&hash);
        self.flush(true);
        (hash, self.seq, self.bytes, self.first_segment_tensor)
    }
}

/// Scan one tensor pair into (nnz, varint index bytes, raw value bytes).
/// `idx_buf`/`val_buf` are cleared and reused across calls.
fn scan_tensor_into(
    o: &[Bf16],
    n: &[Bf16],
    mode: ApplyMode,
    chunk: usize,
    idx_buf: &mut Vec<u8>,
    val_buf: &mut Vec<u8>,
) -> u64 {
    idx_buf.clear();
    val_buf.clear();
    let mut nnz = 0u64;
    let mut prev: Option<u64> = None;
    let len = o.len();
    let mut c = 0usize;
    while c < len {
        let end = (c + chunk).min(len);
        scan_changed(&o[c..end], &n[c..end], |i| {
            let gi = (c + i) as u64;
            let gap = match prev {
                None => gi,
                Some(p) => gi - p,
            };
            varint::write_uleb128(idx_buf, gap);
            prev = Some(gi);
            let v = match mode {
                ApplyMode::Assign => n[c + i],
                ApplyMode::Add => Bf16::from_f32(n[c + i].to_f32() - o[c + i].to_f32()),
            };
            val_buf.extend_from_slice(&v.to_bits().to_le_bytes());
            nnz += 1;
        });
        c = end;
    }
    nnz
}

/// Fused single-pass extract+encode+segment encoder. See the module docs.
pub struct DeltaStreamEncoder {
    version: u64,
    base_version: u64,
    model_fp: u64,
    mode: ApplyMode,
    cfg: StreamConfig,
    pool: FramePool,
}

impl DeltaStreamEncoder {
    pub fn new(
        layout: &ModelLayout,
        base_version: u64,
        version: u64,
        mode: ApplyMode,
        cfg: StreamConfig,
    ) -> DeltaStreamEncoder {
        let mut cfg = cfg;
        cfg.chunk_elems = (cfg.chunk_elems.max(4) / 4) * 4;
        assert!(cfg.segment_bytes > 0, "segment_bytes must be positive");
        DeltaStreamEncoder {
            version,
            base_version,
            model_fp: layout.fingerprint(),
            mode,
            cfg,
            pool: FramePool::new(),
        }
    }

    /// Handle to the frame buffer pool (give it to the transport so frames
    /// recycle after transmission).
    pub fn pool(&self) -> FramePool {
        self.pool.clone()
    }

    /// Single-threaded fused pass: diff `old` vs `new` and hand wire-ready
    /// segments to `sink` as they close.
    pub fn encode<F: FnMut(Segment)>(&self, old: &ParamSet, new: &ParamSet, sink: F) -> StreamStats {
        assert_eq!(old.tensors.len(), new.tensors.len(), "snapshot arity");
        let t0 = Instant::now();
        let mode = self.mode;
        let chunk = self.cfg.chunk_elems;
        let mut em = Emitter::new(self.version, self.cfg.segment_bytes, &self.pool, sink);
        let mut hdr = Vec::with_capacity(encode::HEADER_LEN);
        encode::write_header(&mut hdr, mode, self.version, self.base_version, self.model_fp);
        em.emit(&hdr);
        let mut idx_buf: Vec<u8> = Vec::new();
        let mut val_buf: Vec<u8> = Vec::new();
        let mut nnz_total = 0u64;
        let mut changed = 0u32;
        for (tid, (o, n)) in old.tensors.iter().zip(&new.tensors).enumerate() {
            assert_eq!(o.len(), n.len(), "tensor {tid} length");
            em.cur_tensor = tid as u32;
            let nnz = scan_tensor_into(o, n, mode, chunk, &mut idx_buf, &mut val_buf);
            if nnz > 0 {
                nnz_total += nnz;
                changed += 1;
                em.emit_section(tid as u32, nnz, &idx_buf, &val_buf);
            }
        }
        let (hash, segments, bytes, first) = em.finish();
        StreamStats {
            nnz: nnz_total,
            payload_bytes: bytes,
            segments,
            changed_tensors: changed,
            hash,
            first_segment_tensor: first,
            scan_s: t0.elapsed().as_secs_f64(),
        }
    }

    /// Multi-threaded fused pass: per-tensor shard workers scan
    /// concurrently and feed a bounded queue; the calling thread
    /// re-serializes sections in layout order, hashes, and emits frames.
    /// Byte-identical to [`encode`](Self::encode). Falls back to the
    /// serial path for small models where spawn cost dominates.
    pub fn encode_parallel<F: FnMut(Segment)>(
        &self,
        old: &ParamSet,
        new: &ParamSet,
        threads: usize,
        sink: F,
    ) -> StreamStats {
        assert_eq!(old.tensors.len(), new.tensors.len(), "snapshot arity");
        let total: u64 = old.tensors.iter().map(|t| t.len() as u64).sum();
        let n_tensors = old.tensors.len();
        if threads <= 1 || total < 4_000_000 || n_tensors < 2 {
            return self.encode(old, new, sink);
        }
        let t0 = Instant::now();
        let mode = self.mode;
        let chunk = self.cfg.chunk_elems;
        let threads = threads.min(n_tensors);
        let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, SectionMsg)>(threads * 2);
        std::thread::scope(|scope| {
            for w in 0..threads {
                let tx = tx.clone();
                let old_tensors = &old.tensors;
                let new_tensors = &new.tensors;
                scope.spawn(move || {
                    let mut idx_buf = Vec::new();
                    let mut val_buf = Vec::new();
                    let mut tid = w;
                    while tid < n_tensors {
                        let (o, n) = (&old_tensors[tid], &new_tensors[tid]);
                        assert_eq!(o.len(), n.len(), "tensor {tid} length");
                        let nnz = scan_tensor_into(o, n, mode, chunk, &mut idx_buf, &mut val_buf);
                        let msg = SectionMsg {
                            nnz,
                            idx: std::mem::take(&mut idx_buf),
                            vals: std::mem::take(&mut val_buf),
                        };
                        if tx.send((tid, msg)).is_err() {
                            return; // emitter gone
                        }
                        tid += threads;
                    }
                });
            }
            drop(tx);
            let mut em = Emitter::new(self.version, self.cfg.segment_bytes, &self.pool, sink);
            let mut hdr = Vec::with_capacity(encode::HEADER_LEN);
            encode::write_header(&mut hdr, mode, self.version, self.base_version, self.model_fp);
            em.emit(&hdr);
            let mut pending: BTreeMap<usize, SectionMsg> = BTreeMap::new();
            let mut nnz_total = 0u64;
            let mut changed = 0u32;
            for next in 0..n_tensors {
                let msg = loop {
                    if let Some(m) = pending.remove(&next) {
                        break m;
                    }
                    match rx.recv() {
                        Ok((tid, m)) => {
                            pending.insert(tid, m);
                        }
                        Err(_) => panic!("stream shard worker died before tensor {next}"),
                    }
                };
                em.cur_tensor = next as u32;
                if msg.nnz > 0 {
                    nnz_total += msg.nnz;
                    changed += 1;
                    em.emit_section(next as u32, msg.nnz, &msg.idx, &msg.vals);
                }
            }
            let (hash, segments, bytes, first) = em.finish();
            StreamStats {
                nnz: nnz_total,
                payload_bytes: bytes,
                segments,
                changed_tensors: changed,
                hash,
                first_segment_tensor: first,
                scan_s: t0.elapsed().as_secs_f64(),
            }
        })
    }

    /// Convenience: run the fused pass and collect segments into a vec.
    pub fn encode_to_segments(
        &self,
        old: &ParamSet,
        new: &ParamSet,
    ) -> (Vec<Segment>, StreamStats) {
        let mut segs = Vec::new();
        let stats = self.encode(old, new, |s| segs.push(s));
        (segs, stats)
    }
}

/// Error from the streaming decoder/applier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamError {
    WrongVersion { expected: u64, got: u64 },
    /// Inconsistent totals, out-of-range seq, or duplicate with different
    /// payload — the segment geometry lied.
    GeometryMismatch,
    BadMagic,
    BadFormat(u8),
    BadMode(u8),
    Corrupt(&'static str),
    HashMismatch,
    /// The final segment arrived but the parsed stream needs more bytes.
    Truncated,
    /// The stream parsed to completion but more bytes followed.
    TrailingBytes,
    /// An earlier error poisoned this decoder; discard it.
    Poisoned,
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self)
    }
}

impl std::error::Error for StreamError {}

/// A fully received, hash-verified delta ready for commit.
#[derive(Clone, Debug, PartialEq)]
pub struct StagedDelta {
    pub delta: SparseDelta,
    pub hash: [u8; 32],
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Header,
    SectionHeader,
    Indices,
    Values,
    Trailer,
    Done,
}

struct CurSection {
    tensor: u32,
    nnz: u64,
    idx_bytes: u64,
    idx_consumed: u64,
    idx_count: u64,
    acc: u64,
    idx: Vec<u64>,
    vals: Vec<Bf16>,
}

/// Incremental decoder for the canonical delta stream: parses segments as
/// they arrive (any order, duplicates tolerated), frees payload bytes as
/// they are consumed, verifies the SHA-256 trailer, and yields the parsed
/// [`SparseDelta`] — without ever materializing the checkpoint byte
/// buffer. See the module docs.
pub struct DeltaStreamDecoder {
    version: u64,
    next_seq: u32,
    total: Option<u32>,
    pending: BTreeMap<u32, Segment>,
    buf: Vec<u8>,
    pos: usize,
    hasher: Sha256,
    phase: Phase,
    mode: ApplyMode,
    hdr_version: u64,
    base_version: u64,
    model_fp: u64,
    tensors: Vec<TensorDelta>,
    cur: Option<CurSection>,
    hash: [u8; 32],
    duplicates: u64,
    bytes_consumed: u64,
    poisoned: bool,
    done: bool,
}

impl DeltaStreamDecoder {
    pub fn new(version: u64) -> DeltaStreamDecoder {
        DeltaStreamDecoder {
            version,
            next_seq: 0,
            total: None,
            pending: BTreeMap::new(),
            buf: Vec::new(),
            pos: 0,
            hasher: Sha256::new(),
            phase: Phase::Header,
            mode: ApplyMode::Assign,
            hdr_version: 0,
            base_version: 0,
            model_fp: 0,
            tensors: Vec::new(),
            cur: None,
            hash: [0u8; 32],
            duplicates: 0,
            bytes_consumed: 0,
            poisoned: false,
            done: false,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn is_complete(&self) -> bool {
        self.done
    }

    /// True once an unrecoverable error killed this stream; callers should
    /// discard the decoder (a fresh one can restage from a retransmit).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    pub fn bytes_consumed(&self) -> u64 {
        self.bytes_consumed
    }

    /// Fraction of segments consumed, when the total is known.
    pub fn progress(&self) -> f64 {
        match self.total {
            Some(t) if t > 0 => (self.next_seq as f64 / t as f64).min(1.0),
            _ => 0.0,
        }
    }

    /// Header metadata, once the header has been parsed.
    pub fn header(&self) -> Option<(u64, u64, u64, ApplyMode)> {
        if self.phase == Phase::Header {
            None
        } else {
            Some((self.hdr_version, self.base_version, self.model_fp, self.mode))
        }
    }

    pub(crate) fn mode(&self) -> ApplyMode {
        self.mode
    }

    fn poison(&mut self, e: StreamError) -> StreamError {
        self.poisoned = true;
        e
    }

    /// Feed one segment. Returns `Ok(true)` once the stream is complete
    /// and hash-verified. Duplicates are counted and dropped; out-of-order
    /// segments are buffered until their turn.
    pub fn push(&mut self, seg: Segment) -> Result<bool, StreamError> {
        if self.poisoned {
            return Err(StreamError::Poisoned);
        }
        if seg.version != self.version {
            return Err(StreamError::WrongVersion { expected: self.version, got: seg.version });
        }
        if self.done {
            self.duplicates += 1;
            return Ok(true);
        }
        if seg.total != TOTAL_UNKNOWN {
            match self.total {
                None => {
                    if self.next_seq > seg.total
                        || self.pending.keys().next_back().is_some_and(|&s| s >= seg.total)
                    {
                        return Err(self.poison(StreamError::GeometryMismatch));
                    }
                    self.total = Some(seg.total);
                }
                Some(t) if t != seg.total => {
                    return Err(StreamError::GeometryMismatch);
                }
                _ => {}
            }
        }
        if let Some(t) = self.total {
            if seg.seq >= t {
                return Err(StreamError::GeometryMismatch);
            }
        }
        if seg.seq < self.next_seq {
            self.duplicates += 1;
            return Ok(false);
        }
        if seg.seq > self.next_seq {
            match self.pending.get(&seg.seq) {
                Some(prev) => {
                    if prev.payload != seg.payload {
                        return Err(self.poison(StreamError::GeometryMismatch));
                    }
                    self.duplicates += 1;
                }
                None => {
                    self.pending.insert(seg.seq, seg);
                }
            }
            return Ok(false);
        }
        self.consume(seg)?;
        while let Some(next) = self.pending.remove(&self.next_seq) {
            self.consume(next)?;
        }
        Ok(self.done)
    }

    fn consume(&mut self, seg: Segment) -> Result<(), StreamError> {
        // Drop the consumed prefix so the carry buffer stays tiny (at most
        // one partial field in the in-order case).
        self.buf.drain(..self.pos);
        self.pos = 0;
        self.buf.extend_from_slice(&seg.payload);
        self.next_seq += 1;
        if let Err(e) = self.parse() {
            return Err(self.poison(e));
        }
        if self.done && self.pos < self.buf.len() {
            return Err(self.poison(StreamError::TrailingBytes));
        }
        if let Some(t) = self.total {
            if self.next_seq == t && !self.done {
                return Err(self.poison(StreamError::Truncated));
            }
        }
        Ok(())
    }

    fn avail(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn parse(&mut self) -> Result<(), StreamError> {
        loop {
            match self.phase {
                Phase::Header => {
                    if self.avail() < encode::HEADER_LEN {
                        return Ok(());
                    }
                    let h = &self.buf[self.pos..self.pos + encode::HEADER_LEN];
                    if h[0..4] != encode::MAGIC {
                        return Err(StreamError::BadMagic);
                    }
                    if h[4] != encode::FORMAT_VERSION {
                        return Err(StreamError::BadFormat(h[4]));
                    }
                    let mode =
                        ApplyMode::from_u8(h[5]).ok_or(StreamError::BadMode(h[5]))?;
                    let rd = |a: usize| u64::from_le_bytes(h[a..a + 8].try_into().unwrap());
                    let hdr_version = rd(8);
                    let base_version = rd(16);
                    let model_fp = rd(24);
                    let flags = u32::from_le_bytes(h[32..36].try_into().unwrap());
                    if flags != 0 {
                        return Err(StreamError::Corrupt("unknown header flags"));
                    }
                    if hdr_version != self.version {
                        return Err(StreamError::Corrupt("checkpoint/segment version mismatch"));
                    }
                    self.mode = mode;
                    self.hdr_version = hdr_version;
                    self.base_version = base_version;
                    self.model_fp = model_fp;
                    self.hasher.update(h);
                    self.pos += encode::HEADER_LEN;
                    self.bytes_consumed += encode::HEADER_LEN as u64;
                    self.phase = Phase::SectionHeader;
                }
                Phase::SectionHeader => {
                    if self.avail() < 4 {
                        return Ok(());
                    }
                    let tensor = u32::from_le_bytes(
                        self.buf[self.pos..self.pos + 4].try_into().unwrap(),
                    );
                    if tensor == SECTION_END {
                        self.hasher.update(&self.buf[self.pos..self.pos + 4]);
                        self.pos += 4;
                        self.bytes_consumed += 4;
                        self.phase = Phase::Trailer;
                        continue;
                    }
                    if self.avail() < encode::SECTION_HEADER_LEN {
                        return Ok(());
                    }
                    let h = &self.buf[self.pos..self.pos + encode::SECTION_HEADER_LEN];
                    let nnz = u64::from_le_bytes(h[4..12].try_into().unwrap());
                    let idx_bytes = u64::from_le_bytes(h[12..20].try_into().unwrap());
                    // Plausibility gates bound allocations before the hash
                    // can vouch for the stream: a gap varint is 1..=10
                    // bytes per index.
                    if nnz == 0 {
                        if idx_bytes != 0 {
                            return Err(StreamError::Corrupt("empty section with index bytes"));
                        }
                    } else if idx_bytes < nnz || idx_bytes > nnz.saturating_mul(10) {
                        return Err(StreamError::Corrupt("index section size implausible"));
                    }
                    self.hasher.update(h);
                    self.pos += encode::SECTION_HEADER_LEN;
                    self.bytes_consumed += encode::SECTION_HEADER_LEN as u64;
                    let prealloc = nnz.min(1 << 20) as usize;
                    let cur = CurSection {
                        tensor,
                        nnz,
                        idx_bytes,
                        idx_consumed: 0,
                        idx_count: 0,
                        acc: 0,
                        idx: Vec::with_capacity(prealloc),
                        vals: Vec::with_capacity(prealloc),
                    };
                    if nnz == 0 {
                        self.tensors.push(TensorDelta {
                            tensor,
                            idx: Vec::new(),
                            vals: Vec::new(),
                        });
                        // phase stays SectionHeader
                    } else {
                        self.cur = Some(cur);
                        self.phase = Phase::Indices;
                    }
                }
                Phase::Indices => {
                    let cur = self.cur.as_mut().expect("Indices phase has a section");
                    let start = self.pos;
                    let remaining = (cur.idx_bytes - cur.idx_consumed) as usize;
                    // End of the section's index bytes that are present in
                    // the buffer; stays valid as pos/remaining advance in
                    // lockstep within this window.
                    let window_end = self.pos + remaining.min(self.buf.len() - self.pos);
                    let full_window = window_end == start + remaining;
                    // Parse every varint available in the window, then fold
                    // the whole consumed range into the hash in one update
                    // (per-varint updates would dominate the staging path).
                    while self.pos < window_end {
                        let mut p = self.pos;
                        match varint::read_uleb128(&self.buf[..window_end], &mut p) {
                            Some(gap) => {
                                let used = (p - self.pos) as u64;
                                self.pos = p;
                                cur.idx_consumed += used;
                                cur.acc = if cur.idx_count == 0 {
                                    gap
                                } else {
                                    cur.acc
                                        .checked_add(gap)
                                        .ok_or(StreamError::Corrupt("index overflow"))?
                                };
                                cur.idx.push(cur.acc);
                                cur.idx_count += 1;
                                if cur.idx_count > cur.nnz {
                                    return Err(StreamError::Corrupt("more indices than nnz"));
                                }
                                if cur.idx_consumed == cur.idx_bytes {
                                    if cur.idx_count != cur.nnz {
                                        return Err(StreamError::Corrupt(
                                            "index section length mismatch",
                                        ));
                                    }
                                    self.phase = Phase::Values;
                                    break;
                                }
                            }
                            None => {
                                if full_window {
                                    // All of the section's index bytes are
                                    // here and still unparsable: corrupt.
                                    return Err(StreamError::Corrupt("bad varint stream"));
                                }
                                break; // varint spans the next segment
                            }
                        }
                    }
                    if self.pos > start {
                        self.hasher.update(&self.buf[start..self.pos]);
                        self.bytes_consumed += (self.pos - start) as u64;
                    }
                    if self.phase == Phase::Indices {
                        return Ok(()); // need more bytes
                    }
                }
                Phase::Values => {
                    let cur = self.cur.as_mut().expect("Values phase has a section");
                    let need = (cur.nnz as usize - cur.vals.len()) * 2;
                    let take = need.min(self.avail()) & !1usize;
                    if take == 0 {
                        return Ok(());
                    }
                    let bytes = &self.buf[self.pos..self.pos + take];
                    self.hasher.update(bytes);
                    for pair in bytes.chunks_exact(2) {
                        cur.vals.push(Bf16::from_bits(u16::from_le_bytes([pair[0], pair[1]])));
                    }
                    self.pos += take;
                    self.bytes_consumed += take as u64;
                    if cur.vals.len() == cur.nnz as usize {
                        let cur = self.cur.take().unwrap();
                        self.tensors.push(TensorDelta {
                            tensor: cur.tensor,
                            idx: cur.idx,
                            vals: cur.vals,
                        });
                        self.phase = Phase::SectionHeader;
                    }
                }
                Phase::Trailer => {
                    if self.avail() < 32 {
                        return Ok(());
                    }
                    let hasher = std::mem::replace(&mut self.hasher, Sha256::new());
                    let expect = hasher.finalize();
                    if self.buf[self.pos..self.pos + 32] != expect[..] {
                        return Err(StreamError::HashMismatch);
                    }
                    self.hash = expect;
                    self.pos += 32;
                    self.bytes_consumed += 32;
                    self.done = true;
                    self.phase = Phase::Done;
                    return Ok(());
                }
                Phase::Done => return Ok(()),
            }
        }
    }

    /// Drain the tensor sections parsed so far (used by the streaming
    /// applier so its working set stays one section).
    pub(crate) fn take_completed_sections(&mut self) -> Vec<TensorDelta> {
        std::mem::take(&mut self.tensors)
    }

    /// Consume the decoder into the verified delta (None until complete).
    pub fn into_staged(self) -> Option<StagedDelta> {
        if !self.done {
            return None;
        }
        Some(StagedDelta {
            delta: SparseDelta {
                version: self.hdr_version,
                base_version: self.base_version,
                model_fp: self.model_fp,
                mode: self.mode,
                tensors: self.tensors,
            },
            hash: self.hash,
        })
    }
}

/// Streaming scatter-assign: applies each completed tensor section to the
/// parameters as its bytes arrive, with an undo log so a trailer-hash
/// mismatch (or any mid-stream corruption) rolls the parameters back
/// bit-exactly. Use at a safe point only — the parameters mutate while the
/// stream is in flight.
pub struct DeltaStreamApplier {
    dec: DeltaStreamDecoder,
    undo: Vec<(u32, u64, Bf16)>,
    applied_nnz: u64,
}

impl DeltaStreamApplier {
    pub fn new(version: u64) -> DeltaStreamApplier {
        DeltaStreamApplier { dec: DeltaStreamDecoder::new(version), undo: Vec::new(), applied_nnz: 0 }
    }

    pub fn is_complete(&self) -> bool {
        self.dec.is_complete()
    }

    pub fn applied_nnz(&self) -> u64 {
        self.applied_nnz
    }

    /// Header metadata once parsed (for base-version gating by the caller).
    pub fn header(&self) -> Option<(u64, u64, u64, ApplyMode)> {
        self.dec.header()
    }

    /// The verified stream hash (valid once complete).
    pub fn hash(&self) -> Option<[u8; 32]> {
        self.dec.is_complete().then_some(self.dec.hash)
    }

    /// Feed one segment, applying completed sections to `params`. On any
    /// error every applied element is rolled back before returning.
    pub fn push(
        &mut self,
        seg: Segment,
        params: &mut ParamSet,
    ) -> Result<bool, StreamError> {
        let done = match self.dec.push(seg) {
            Ok(d) => d,
            Err(e) => {
                // Roll back only when the stream itself is dead (poisoned).
                // Non-poisoning rejections (a stray segment from another
                // version, an inconsistent-geometry frame) leave the stream
                // recoverable, and already-applied sections must survive so
                // the remaining segments complete correctly.
                if self.dec.is_poisoned() {
                    self.rollback(params);
                }
                return Err(e);
            }
        };
        let mode = self.dec.mode();
        for t in self.dec.take_completed_sections() {
            let in_bounds = (t.tensor as usize) < params.tensors.len()
                && t.idx
                    .last()
                    .map(|&i| (i as usize) < params.tensors[t.tensor as usize].len())
                    .unwrap_or(true)
                && t.idx.windows(2).all(|w| w[0] < w[1]);
            if !in_bounds {
                self.dec.poisoned = true;
                self.rollback(params);
                return Err(StreamError::Corrupt("section addresses out of bounds"));
            }
            let buf = &mut params.tensors[t.tensor as usize];
            for (&i, &v) in t.idx.iter().zip(&t.vals) {
                let slot = &mut buf[i as usize];
                self.undo.push((t.tensor, i, *slot));
                *slot = match mode {
                    ApplyMode::Assign => v,
                    ApplyMode::Add => Bf16::from_f32(slot.to_f32() + v.to_f32()),
                };
                self.applied_nnz += 1;
            }
        }
        if done {
            self.undo.clear(); // committed: hash verified
        }
        Ok(done)
    }

    fn rollback(&mut self, params: &mut ParamSet) {
        for (tensor, i, old) in self.undo.drain(..).rev() {
            params.tensors[tensor as usize][i as usize] = old;
        }
        self.applied_nnz = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::encode::{decode_delta, delta_hash, encode_delta};
    use crate::delta::extract::{apply_delta, extract_delta};
    use crate::transport::segment::split_into_segments;
    use crate::util::{prop, Rng};

    fn perturbed(p: &ParamSet, rho: f64, rng: &mut Rng) -> ParamSet {
        let mut q = p.clone();
        for t in &mut q.tensors {
            let n = t.len();
            let k = ((n as f64 * rho).round() as usize).clamp(1, n);
            for i in prop::sparse_indices(rng, n as u64, k) {
                let v = &mut t[i as usize];
                *v = Bf16::from_bits(v.to_bits() ^ 0x0040);
            }
        }
        q
    }

    fn setup(rho: f64, seed: u64) -> (ModelLayout, ParamSet, ParamSet) {
        let l = ModelLayout::transformer("t", 256, 64, 2, 128);
        let mut rng = Rng::new(seed);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let new = perturbed(&old, rho, &mut rng);
        (l, old, new)
    }

    fn concat(segs: &[Segment]) -> Vec<u8> {
        segs.iter().flat_map(|s| s.payload.iter().copied()).collect()
    }

    #[test]
    fn bit_identical_to_legacy_encode_across_densities() {
        for (i, rho) in [0.0005, 0.01, 0.08, 0.5].iter().enumerate() {
            let (l, old, new) = setup(*rho, 100 + i as u64);
            let legacy = encode_delta(&extract_delta(&l, &old, &new, 3, 4, ApplyMode::Assign));
            let enc = DeltaStreamEncoder::new(
                &l,
                3,
                4,
                ApplyMode::Assign,
                StreamConfig { segment_bytes: 1 << 12, ..Default::default() },
            );
            let (segs, stats) = enc.encode_to_segments(&old, &new);
            let streamed = concat(&segs);
            assert_eq!(streamed, legacy, "rho={rho}");
            assert_eq!(Some(stats.hash), delta_hash(&legacy), "same trailing hash");
            assert_eq!(stats.payload_bytes as usize, legacy.len());
            assert_eq!(stats.segments as usize, segs.len());
        }
    }

    #[test]
    fn add_mode_is_bit_identical_too() {
        let (l, old, new) = setup(0.02, 7);
        let legacy = encode_delta(&extract_delta(&l, &old, &new, 0, 1, ApplyMode::Add));
        let enc = DeltaStreamEncoder::new(&l, 0, 1, ApplyMode::Add, StreamConfig::default());
        let (segs, _) = enc.encode_to_segments(&old, &new);
        assert_eq!(concat(&segs), legacy);
    }

    #[test]
    fn segment_geometry_matches_legacy_split() {
        let (l, old, new) = setup(0.05, 9);
        let legacy = encode_delta(&extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign));
        let seg_bytes = 700usize;
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: seg_bytes, ..Default::default() },
        );
        let (segs, _) = enc.encode_to_segments(&old, &new);
        let split = split_into_segments(1, &legacy, seg_bytes);
        assert_eq!(segs.len(), split.len());
        for (a, b) in segs.iter().zip(&split) {
            assert_eq!(a.payload, b.payload);
            assert_eq!(a.seq, b.seq);
        }
        // Streaming totals: unknown everywhere except the final frame.
        for s in &segs[..segs.len() - 1] {
            assert_eq!(s.total, TOTAL_UNKNOWN);
        }
        assert_eq!(segs.last().unwrap().total, segs.len() as u32);
    }

    #[test]
    fn first_segment_leaves_before_scan_completes() {
        // Make the early tensors produce more than one segment's worth of
        // encoded bytes so frames must ship mid-scan.
        let (l, old, new) = setup(0.10, 11);
        let n_tensors = l.tensors.len() as u32;
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 1 << 10, ..Default::default() },
        );
        let (segs, stats) = enc.encode_to_segments(&old, &new);
        assert!(segs.len() > 3, "need a multi-segment stream");
        let at = stats
            .first_segment_tensor
            .expect("first segment must ship during the scan");
        assert!(
            at < n_tensors - 1,
            "first segment left at tensor {at}/{n_tensors}: no overlap"
        );
    }

    #[test]
    fn parallel_encode_is_byte_identical_and_stats_match() {
        let l = ModelLayout::transformer("p", 512, 128, 4, 512);
        let mut rng = Rng::new(13);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let new = perturbed(&old, 0.03, &mut rng);
        let enc = DeltaStreamEncoder::new(
            &l,
            1,
            2,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 1 << 12, ..Default::default() },
        );
        let (serial, s_stats) = enc.encode_to_segments(&old, &new);
        let mut par = Vec::new();
        // Force the parallel path even though the model is small.
        let total: u64 = old.tensors.iter().map(|t| t.len() as u64).sum();
        assert!(total < 4_000_000, "test model should be below the fallback bound");
        let p_stats = {
            // Bypass the size fallback by calling with a big-model clone of
            // the config logic: use encode_parallel on a padded model is
            // overkill; instead exercise the worker path directly.
            let mut q_old = old.clone();
            let mut q_new = new.clone();
            // Pad with one large unchanged tensor to cross the threshold
            // without altering the diff (unchanged => no section).
            q_old.tensors.push(vec![Bf16::ZERO; 4_000_000]);
            q_new.tensors.push(vec![Bf16::ZERO; 4_000_000]);
            enc.encode_parallel(&q_old, &q_new, 4, |s| par.push(s))
        };
        assert_eq!(concat(&par), concat(&serial));
        assert_eq!(p_stats.nnz, s_stats.nnz);
        assert_eq!(p_stats.hash, s_stats.hash);
    }

    #[test]
    fn decoder_in_order_round_trips() {
        let (l, old, new) = setup(0.02, 17);
        let delta = extract_delta(&l, &old, &new, 5, 6, ApplyMode::Assign);
        let enc = DeltaStreamEncoder::new(
            &l,
            5,
            6,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 900, ..Default::default() },
        );
        let (segs, stats) = enc.encode_to_segments(&old, &new);
        let mut dec = DeltaStreamDecoder::new(6);
        let mut became = false;
        for s in segs {
            became |= dec.push(s).unwrap();
        }
        assert!(became && dec.is_complete());
        let staged = dec.into_staged().unwrap();
        assert_eq!(staged.delta, delta);
        assert_eq!(staged.hash, stats.hash);
    }

    #[test]
    fn decoder_tolerates_reordering_and_duplicates() {
        let (l, old, new) = setup(0.03, 19);
        let delta = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign);
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 500, ..Default::default() },
        );
        let (segs, _) = enc.encode_to_segments(&old, &new);
        let mut rng = Rng::new(3);
        let mut chaos: Vec<Segment> = segs.clone();
        let dups: Vec<Segment> = segs.iter().step_by(2).cloned().collect();
        chaos.extend(dups);
        rng.shuffle(&mut chaos);
        let mut dec = DeltaStreamDecoder::new(1);
        for s in chaos {
            dec.push(s).unwrap();
        }
        assert!(dec.is_complete());
        assert!(dec.duplicates() > 0);
        assert_eq!(dec.into_staged().unwrap().delta, delta);
    }

    #[test]
    fn decoder_detects_corruption_and_poisons() {
        let (l, old, new) = setup(0.02, 23);
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 600, ..Default::default() },
        );
        let (mut segs, _) = enc.encode_to_segments(&old, &new);
        let n = segs.len();
        assert!(n > 2);
        // Corrupt one payload byte in the middle of the stream: either the
        // parser rejects it structurally or the final hash check fails.
        segs[n / 2].payload[3] ^= 0xFF;
        let mut dec = DeltaStreamDecoder::new(1);
        let mut failed = false;
        for s in segs {
            if dec.push(s).is_err() {
                failed = true;
            }
        }
        assert!(failed, "corruption must surface as an error");
        assert!(!dec.is_complete());
        // Poisoned decoders refuse further input.
        assert_eq!(
            dec.push(Segment { version: 1, seq: 0, total: TOTAL_UNKNOWN, payload: vec![] }),
            Err(StreamError::Poisoned)
        );
    }

    #[test]
    fn decoder_rejects_wrong_version_and_geometry() {
        let mut dec = DeltaStreamDecoder::new(4);
        let wrong = Segment { version: 5, seq: 0, total: 2, payload: vec![1, 2] };
        assert!(matches!(
            dec.push(wrong),
            Err(StreamError::WrongVersion { expected: 4, got: 5 })
        ));
        // Conflicting totals.
        let a = Segment { version: 4, seq: 1, total: 3, payload: vec![0] };
        let b = Segment { version: 4, seq: 2, total: 9, payload: vec![0] };
        dec.push(a).unwrap();
        assert_eq!(dec.push(b), Err(StreamError::GeometryMismatch));
    }

    #[test]
    fn applier_matches_apply_delta_and_rolls_back_on_corruption() {
        let (l, old, new) = setup(0.04, 29);
        let delta = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign);
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 800, ..Default::default() },
        );
        let (segs, _) = enc.encode_to_segments(&old, &new);

        // Clean stream: streaming scatter-assign == buffered apply_delta.
        let mut via_stream = old.clone();
        let mut ap = DeltaStreamApplier::new(1);
        let mut done = false;
        for s in segs.clone() {
            done |= ap.push(s, &mut via_stream).unwrap();
        }
        assert!(done);
        assert_eq!(ap.applied_nnz(), delta.nnz());
        let mut via_buffer = old.clone();
        apply_delta(&mut via_buffer, &delta);
        assert_eq!(via_stream, via_buffer);
        assert_eq!(via_stream, new, "assign mode reproduces the snapshot");

        // Corrupted stream: values scatter in flight, then the hash check
        // fails and the rollback restores the original parameters.
        let mut corrupted = segs;
        let last = corrupted.len() - 1;
        // Flip a value byte early so sections DO get applied before the
        // trailer check fails.
        corrupted[0].payload[encode::HEADER_LEN + encode::SECTION_HEADER_LEN + 1] ^= 0x10;
        let mut params = old.clone();
        let mut ap = DeltaStreamApplier::new(1);
        let mut saw_err = false;
        for (i, s) in corrupted.into_iter().enumerate() {
            match ap.push(s, &mut params) {
                Ok(_) => {}
                Err(e) => {
                    saw_err = true;
                    assert!(i == last || matches!(e, StreamError::Poisoned | StreamError::Corrupt(_)));
                }
            }
        }
        assert!(saw_err);
        assert_eq!(params, old, "rollback must restore parameters bit-exactly");
    }

    #[test]
    fn applier_survives_stray_segment_without_reverting() {
        // A non-poisoning rejection (segment from another version) must
        // not roll back sections that already applied — the real stream
        // still completes and must land bit-exact.
        let (l, old, new) = setup(0.04, 41);
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 800, ..Default::default() },
        );
        let (segs, _) = enc.encode_to_segments(&old, &new);
        assert!(segs.len() > 2);
        let mut params = old.clone();
        let mut ap = DeltaStreamApplier::new(1);
        let mut done = false;
        for (k, s) in segs.iter().enumerate() {
            if k == segs.len() / 2 {
                let stray = Segment {
                    version: 9,
                    seq: 0,
                    total: TOTAL_UNKNOWN,
                    payload: vec![1, 2, 3],
                };
                assert!(matches!(
                    ap.push(stray, &mut params),
                    Err(StreamError::WrongVersion { expected: 1, got: 9 })
                ));
            }
            done |= ap.push(s.clone(), &mut params).unwrap();
        }
        assert!(done);
        assert_eq!(params, new, "stray segment must not corrupt the apply");
    }

    #[test]
    fn empty_delta_streams_as_one_segment() {
        let (l, old, _) = setup(0.01, 31);
        let enc = DeltaStreamEncoder::new(&l, 2, 3, ApplyMode::Assign, StreamConfig::default());
        let (segs, stats) = enc.encode_to_segments(&old, &old);
        assert_eq!(stats.nnz, 0);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].total, 1);
        assert_eq!(stats.first_segment_tensor, None, "single frame => no overlap");
        let legacy = encode_delta(&extract_delta(&l, &old, &old, 2, 3, ApplyMode::Assign));
        assert_eq!(concat(&segs), legacy);
        let mut dec = DeltaStreamDecoder::new(3);
        assert!(dec.push(segs[0].clone()).unwrap());
        let staged = dec.into_staged().unwrap();
        assert_eq!(staged.delta.nnz(), 0);
        assert_eq!(staged.delta.base_version, 2);
    }

    #[test]
    fn frame_pool_recycles_buffers() {
        let (l, old, new) = setup(0.05, 37);
        let enc = DeltaStreamEncoder::new(
            &l,
            0,
            1,
            ApplyMode::Assign,
            StreamConfig { segment_bytes: 512, ..Default::default() },
        );
        let pool = enc.pool();
        let mut n = 0usize;
        enc.encode(&old, &new, |seg| {
            n += 1;
            pool.recycle(seg.payload); // transport done with the frame
        });
        assert!(n > 2);
        assert!(!pool.is_empty(), "recycled frames return to the pool");
        // Second encode draws from the pool rather than allocating.
        let before = pool.len();
        enc.encode(&old, &new, |seg| pool.recycle(seg.payload));
        assert!(pool.len() >= before.min(1));
    }

    #[test]
    fn prop_stream_and_legacy_paths_agree_and_apply_bit_exact() {
        // Satellite: extract -> encode -> decode -> apply bit-exactness at
        // densities 0.01% .. 50%, streaming and legacy byte-identical.
        prop::check("stream/legacy byte identity + apply", 20, |rng| {
            let l = ModelLayout::new(
                "p",
                vec![
                    super::super::TensorSpec::new("a", &[rng.range(1, 4000)]),
                    super::super::TensorSpec::new("b", &[rng.range(1, 4000)]),
                    super::super::TensorSpec::new("c", &[rng.range(1, 400)]),
                ],
            );
            let old = ParamSet::random(&l, 0.05, rng);
            // Log-uniform density in [1e-4, 0.5].
            let rho = 10f64.powf(-4.0 + rng.f64() * (f64::log10(0.5) + 4.0));
            let mut new = old.clone();
            for t in &mut new.tensors {
                let n = t.len();
                let k = ((n as f64 * rho).round() as usize).min(n);
                for i in prop::sparse_indices(rng, n as u64, k) {
                    t[i as usize] = Bf16::from_bits(rng.next_u64() as u16);
                }
            }
            let delta = extract_delta(&l, &old, &new, 7, 8, ApplyMode::Assign);
            let legacy = encode_delta(&delta);
            let seg_bytes = rng.range(64, 4096);
            let enc = DeltaStreamEncoder::new(
                &l,
                7,
                8,
                ApplyMode::Assign,
                StreamConfig { segment_bytes: seg_bytes, chunk_elems: rng.range(4, 512) },
            );
            let (segs, stats) = enc.encode_to_segments(&old, &new);
            assert_eq!(concat(&segs), legacy, "streaming and legacy bytes identical");
            assert_eq!(Some(stats.hash), delta_hash(&legacy));
            // decode (legacy) and streaming decode agree...
            let via_legacy = decode_delta(&legacy).unwrap();
            let mut dec = DeltaStreamDecoder::new(8);
            for s in segs {
                dec.push(s).unwrap();
            }
            let via_stream = dec.into_staged().unwrap().delta;
            assert_eq!(via_legacy, via_stream);
            // ...and applying reproduces the snapshot bit-exactly.
            let mut applied = old.clone();
            apply_delta(&mut applied, &via_stream);
            assert_eq!(applied, new);
        });
    }
}
