//! Multi-run model registry: N fine-tunes stored as compacted delta
//! chains off one shared, content-addressed base object — the serving
//! side of the paper's lossless-sparse-delta trick (ROADMAP item 4, the
//! gagansuie/sparse workload: many adapters, one base, O(rho) bytes per
//! model instead of O(N) dense snapshots).
//!
//! Layout under a registry directory:
//!
//! ```text
//! registry_dir/
//!   registry.json            layout marker: {"schema": 1}
//!   objects/<sha256>.sprw    shared content-addressed pool — base policy
//!                            snapshots AND folded delta artifacts; one
//!                            byte-identical object is stored exactly once
//!                            no matter how many models reference it
//!   bases/<sha256>           base ref: {"model_fp", "bytes"}
//!   models/<name>/model.json per-model manifest: base sha + one entry per
//!                            published version {version, object, witness,
//!                            payload_bytes}
//! ```
//!
//! Publishing a run folds its durable chain `D_1..D_w` through
//! [`merge_chain`] into one artifact, verifies the fold reproduces the
//! run's journaled witness, and writes everything content-addressed —
//! so cross-run deduplication (N runs off one base, or two determinism
//! replicas of the same run) falls out of the addressing for free.
//!
//! The **hot-swap composition**: to retarget an actor holding fine-tune
//! A@v onto B@w without shipping a dense snapshot, ship
//! `merge_chain([invert(chain_A vs base), chain_B])` — an Assign-mode
//! delta over `support(A) ∪ support(B)` that resets A-only slots to base
//! values and writes B's values everywhere it touched. Applied to the
//! exact bits of A@v it yields the exact bits of B@w ([`swap_delta`],
//! property-tested in `tests/registry_swap.rs`). The runtime drives it
//! through the ordinary Seg/Commit staging machinery
//! (`rt::pipeline::run_swap_script_*`).
//!
//! GC: objects are collected only when no model manifest references them
//! AND no in-flight swap pin ([`SwapPin`]) holds them — the same counted
//! pin idiom [`CheckpointStore::pin_chain`] uses for pending bootstraps,
//! so a concurrent `gc` can never reclaim a base or version a swap
//! composition is still reading.
//!
//! [`CheckpointStore::pin_chain`]: crate::delta::CheckpointStore::pin_chain

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use sha2::{Digest, Sha256};

use crate::actor::invert_delta;
use crate::delta::store::parse_hash;
use crate::delta::{
    apply_delta, merge_chain, policy_witness, DeltaCheckpoint, DurableStore, MergeError,
    ModelLayout, ParamSet, RecoveryError, SparseDelta,
};
use crate::util::jsonl::Json;
use crate::util::hex;

/// Compose the sparse delta that moves a policy holding fine-tune
/// `from` (applied over `base`) onto fine-tune `to` (over the same
/// `base`), without materializing either dense policy on the wire.
///
/// `from` and `to` must be Assign-mode deltas off the same base version
/// of the same model. The result spans `from.version -> to.version` and
/// its support is `support(from) ∪ support(to)`: slots only `from`
/// touched are reset to their base values, slots `to` touched get `to`'s
/// values (last-writer-wins). Applying it to the exact bits of
/// `base + from` yields the exact bits of `base + to` — bit-exact
/// because every write is a re-assignment of captured bf16 bits, never
/// arithmetic.
pub fn swap_delta(
    base: &ParamSet,
    from: &SparseDelta,
    to: &SparseDelta,
) -> Result<SparseDelta, MergeError> {
    if from.model_fp != to.model_fp {
        return Err(MergeError::ModelMismatch);
    }
    if from.base_version != to.base_version {
        return Err(MergeError::NonContiguous {
            expected: from.base_version,
            found: to.base_version,
        });
    }
    // invert(from) spans from.version -> base; chaining `to` back out of
    // the base satisfies merge_chain's contiguity check naturally.
    let inv = invert_delta(base, from);
    merge_chain(&[inv, to.clone()])
}

/// One published version of a model: the folded-chain object plus the
/// journaled witness it must reconstruct to.
#[derive(Debug, Clone)]
pub struct VersionRef {
    /// Version (in the source run's numbering) this object folds up to.
    pub version: u64,
    /// Content address of the folded delta artifact.
    pub object: String,
    /// SHA-256 policy witness of the reconstructed policy at `version`.
    pub witness: [u8; 32],
    /// Encoded bytes of the folded artifact.
    pub payload_bytes: u64,
}

/// One model's manifest: which base it fine-tunes and the versions
/// published for it.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    /// Model name (the `models/<name>/` directory).
    pub name: String,
    /// Layout fingerprint shared by every version.
    pub model_fp: u64,
    /// Content address of the shared base policy snapshot.
    pub base: String,
    /// Published versions, ascending.
    pub versions: Vec<VersionRef>,
}

/// Base-object bookkeeping (`bases/<sha>` ref files).
#[derive(Debug, Clone)]
pub struct BaseRef {
    /// Layout fingerprint of the snapshot.
    pub model_fp: u64,
    /// Dense snapshot bytes (2 per parameter).
    pub bytes: u64,
}

/// What [`ModelRegistry::publish`] did.
#[derive(Debug, Clone)]
pub struct PublishReport {
    /// Model name published under.
    pub model: String,
    /// Version published.
    pub version: u64,
    /// Content address of the folded chain artifact.
    pub object: String,
    /// Encoded bytes of the folded artifact.
    pub payload_bytes: u64,
    /// Content address of the (shared) base object.
    pub base: String,
    /// Dense bytes of the base snapshot.
    pub base_bytes: u64,
    /// `false` when the base object already existed (cross-run dedup hit).
    pub base_was_new: bool,
    /// `false` when the folded object already existed (identical chain
    /// already published — e.g. a determinism replica).
    pub object_was_new: bool,
}

/// What [`ModelRegistry::gc`] swept.
#[derive(Debug, Clone, Copy, Default)]
pub struct GcStats {
    /// Objects examined in the pool.
    pub scanned: usize,
    /// Unreferenced, unpinned objects removed.
    pub collected: usize,
    /// Bytes those objects held.
    pub collected_bytes: u64,
    /// Objects kept **only** because a swap pin holds them.
    pub retained_pinned: usize,
}

/// Counted object pins shared between a registry and its outstanding
/// [`SwapPin`] guards (object id -> pin count).
type PinMap = Arc<Mutex<BTreeMap<String, usize>>>;

/// RAII guard over the objects a swap-delta composition reads (source
/// object, target object, shared base). While any guard is alive,
/// [`ModelRegistry::gc`] keeps those objects even if every manifest
/// referencing them is unpublished mid-swap — the registry mirror of the
/// pending-bootstrap chain pin. Dropping the guard releases the pins.
pub struct SwapPin {
    pins: PinMap,
    ids: Vec<String>,
}

impl Drop for SwapPin {
    fn drop(&mut self) {
        let mut pins = self.pins.lock().expect("registry pin map poisoned");
        for id in &self.ids {
            if let Some(count) = pins.get_mut(id) {
                *count -= 1;
                if *count == 0 {
                    pins.remove(id);
                }
            }
        }
    }
}

/// Multi-run namespace over content-addressed objects. See the module
/// docs for layout and invariants.
pub struct ModelRegistry {
    root: PathBuf,
    models: BTreeMap<String, ModelManifest>,
    bases: BTreeMap<String, BaseRef>,
    pins: PinMap,
}

/// A registry directory must never be confused with a single-run
/// [`DurableStore`] persist dir: both hold an `objects/` pool, but a run
/// dir has a `journal.jsonl` and a registry has a `registry.json`
/// marker. Returns [`RecoveryError::NotARun`] when `dir` is a registry.
pub fn expect_run_dir(dir: &Path) -> Result<(), RecoveryError> {
    if dir.join("registry.json").exists() {
        return Err(RecoveryError::NotARun { path: dir.to_path_buf() });
    }
    Ok(())
}

fn valid_model_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.')
        && !name.starts_with('.')
}

impl ModelRegistry {
    /// Open (creating if absent) a registry directory. A directory
    /// already holding a single-run durable store is rejected with
    /// [`RecoveryError::NotARegistry`] instead of being silently
    /// converted; a fresh/empty directory is initialized with the
    /// `registry.json` marker.
    pub fn open(root: impl Into<PathBuf>) -> Result<ModelRegistry, RecoveryError> {
        let root = root.into();
        let marker = root.join("registry.json");
        if !marker.exists() {
            if root.join("journal.jsonl").exists() {
                return Err(RecoveryError::NotARegistry { path: root });
            }
            fs::create_dir_all(&root)?;
            write_atomic(&root, &marker, Json::obj().set("schema", 1u64).to_string().as_bytes())?;
        }
        fs::create_dir_all(root.join("objects"))?;
        fs::create_dir_all(root.join("bases"))?;
        fs::create_dir_all(root.join("models"))?;
        let mut reg = ModelRegistry {
            root,
            models: BTreeMap::new(),
            bases: BTreeMap::new(),
            pins: Arc::new(Mutex::new(BTreeMap::new())),
        };
        reg.load_bases()?;
        reg.load_models()?;
        Ok(reg)
    }

    /// Directory this registry lives under.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// All published models, by name.
    pub fn models(&self) -> &BTreeMap<String, ModelManifest> {
        &self.models
    }

    /// All recorded bases, by content address.
    pub fn bases(&self) -> &BTreeMap<String, BaseRef> {
        &self.bases
    }

    /// Manifest of `name`, or [`RecoveryError::UnknownModel`].
    pub fn model(&self, name: &str) -> Result<&ModelManifest, RecoveryError> {
        self.models
            .get(name)
            .ok_or_else(|| RecoveryError::UnknownModel { model: name.to_string() })
    }

    /// The published `version` of `name`, or a typed unknown-model /
    /// unknown-version error.
    pub fn version_ref(&self, name: &str, version: u64) -> Result<&VersionRef, RecoveryError> {
        self.model(name)?
            .versions
            .iter()
            .find(|v| v.version == version)
            .ok_or_else(|| RecoveryError::UnknownModelVersion {
                model: name.to_string(),
                version,
            })
    }

    /// Journaled policy witness of `name@version`.
    pub fn witness(&self, name: &str, version: u64) -> Result<[u8; 32], RecoveryError> {
        Ok(self.version_ref(name, version)?.witness)
    }

    /// Locate which published `(model, version)` a live policy witness
    /// corresponds to — how the runtime identifies the fine-tune an
    /// actor currently holds before composing a swap away from it.
    pub fn locate(&self, witness: &[u8; 32]) -> Option<(String, u64)> {
        for (name, m) in &self.models {
            for v in &m.versions {
                if &v.witness == witness {
                    return Some((name.clone(), v.version));
                }
            }
        }
        None
    }

    // ------------------------------------------------------------------
    // Object pool
    // ------------------------------------------------------------------

    fn object_path(&self, id: &str) -> PathBuf {
        self.root.join("objects").join(format!("{id}.sprw"))
    }

    /// Content-addressed write (tmp + fsync + rename). Returns the id
    /// and whether the object was actually new — `false` is the dedup
    /// hit the registry exists for.
    fn put_object(&self, bytes: &[u8]) -> Result<(String, bool), RecoveryError> {
        let id = hex(&Sha256::digest(bytes));
        let path = self.object_path(&id);
        if path.exists() {
            return Ok((id, false));
        }
        let tmp = self.root.join("objects").join(format!(".{id}.tmp"));
        write_atomic_at(&tmp, &path, bytes)?;
        Ok((id, true))
    }

    /// Read and content-verify an object from the pool.
    fn read_object(&self, id: &str, referenced_by: u64) -> Result<Vec<u8>, RecoveryError> {
        let path = self.object_path(id);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(RecoveryError::MissingObject {
                    version: referenced_by,
                    id: id.to_string(),
                })
            }
            Err(e) => return Err(e.into()),
        };
        if hex(&Sha256::digest(&bytes)) != id {
            return Err(RecoveryError::ObjectHashMismatch {
                version: referenced_by,
                id: id.to_string(),
            });
        }
        Ok(bytes)
    }

    // ------------------------------------------------------------------
    // Manifest persistence
    // ------------------------------------------------------------------

    fn load_bases(&mut self) -> Result<(), RecoveryError> {
        for entry in fs::read_dir(self.root.join("bases"))? {
            let path = entry?.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("").to_string();
            if name.starts_with('.') || parse_hash(&name).is_none() {
                continue;
            }
            let raw = fs::read_to_string(&path)?;
            let j = Json::parse(raw.trim()).map_err(|reason| RecoveryError::CorruptManifest {
                version: 0,
                reason: format!("base ref {name}: {reason}"),
            })?;
            let corrupt = |what: &str| RecoveryError::CorruptManifest {
                version: 0,
                reason: format!("base ref {name}: missing {what}"),
            };
            let model_fp = j
                .get("model_fp")
                .and_then(Json::as_str)
                .and_then(parse_u64_hex)
                .ok_or_else(|| corrupt("model_fp"))?;
            let bytes = j.get("bytes").and_then(Json::as_u64).ok_or_else(|| corrupt("bytes"))?;
            self.bases.insert(name, BaseRef { model_fp, bytes });
        }
        Ok(())
    }

    fn load_models(&mut self) -> Result<(), RecoveryError> {
        for entry in fs::read_dir(self.root.join("models"))? {
            let dir = entry?.path();
            if !dir.is_dir() {
                continue;
            }
            let name = dir.file_name().and_then(|s| s.to_str()).unwrap_or("").to_string();
            if !valid_model_name(&name) {
                continue;
            }
            let raw = fs::read_to_string(dir.join("model.json"))?;
            let manifest = manifest_from_json(&name, raw.trim())?;
            self.models.insert(name, manifest);
        }
        Ok(())
    }

    fn write_manifest(&self, m: &ModelManifest) -> Result<(), RecoveryError> {
        let dir = self.root.join("models").join(&m.name);
        fs::create_dir_all(&dir)?;
        let versions: Vec<Json> = m
            .versions
            .iter()
            .map(|v| {
                Json::obj()
                    .set("version", v.version)
                    .set("object", v.object.as_str())
                    .set("witness", hex(&v.witness))
                    .set("payload_bytes", v.payload_bytes)
            })
            .collect();
        let j = Json::obj()
            .set("schema", 1u64)
            .set("model_fp", format!("{:016x}", m.model_fp))
            .set("base", m.base.as_str())
            .set("versions", Json::Arr(versions));
        write_atomic(&dir, &dir.join("model.json"), j.to_string().as_bytes())
    }

    // ------------------------------------------------------------------
    // Publish / unpublish
    // ------------------------------------------------------------------

    /// Publish `store`'s chain (folded up to `version`, defaulting to the
    /// last journaled commit) under `name`. The base snapshot and the
    /// folded artifact land in the shared pool content-addressed, so N
    /// fine-tunes off one base store that base exactly once. The fold is
    /// verified against the run's journaled witness before any manifest
    /// is written. Re-publishing identical bytes is idempotent;
    /// contradicting what the registry already records is a typed
    /// [`RecoveryError::RegistryConflict`].
    pub fn publish(
        &mut self,
        store: &DurableStore,
        layout: &ModelLayout,
        name: &str,
        version: Option<u64>,
    ) -> Result<PublishReport, RecoveryError> {
        if !valid_model_name(name) {
            return Err(RecoveryError::RegistryConflict {
                model: name.to_string(),
                reason: "model names are [A-Za-z0-9._-]+ (and must not start with '.')".into(),
            });
        }
        let last = store
            .last_version()
            .ok_or(RecoveryError::UnknownVersion { version: 0 })?;
        let w = version.unwrap_or(last);
        if w == 0 || w > last {
            return Err(RecoveryError::UnknownVersion { version: w });
        }
        let base_policy = store.base_policy(layout)?;
        let base_bytes = base_policy.to_snapshot_bytes();

        // Fold D_1..D_w and verify against the journaled witness before
        // anything becomes visible.
        let mut chain = Vec::with_capacity(w as usize);
        for v in 1..=w {
            let ckpt = store.delta(v)?;
            chain.push(ckpt.open().map_err(|error| RecoveryError::CorruptArtifact {
                path: store.root().join("objects"),
                error,
            })?);
        }
        let folded = merge_chain(&chain)?;
        let witness = store.witness(w)?;
        let mut check = base_policy.clone();
        apply_delta(&mut check, &folded);
        if policy_witness(&check) != witness {
            return Err(RecoveryError::WitnessMismatch { version: w });
        }
        let artifact = DeltaCheckpoint::seal(&folded);

        let (base_id, base_was_new) = self.put_object(&base_bytes)?;
        let (object_id, object_was_new) = self.put_object(&artifact.bytes)?;
        let fp = layout.fingerprint();

        // Base ref bookkeeping (idempotent).
        if !self.bases.contains_key(&base_id) {
            let j = Json::obj()
                .set("model_fp", format!("{fp:016x}"))
                .set("bytes", base_bytes.len() as u64);
            let dir = self.root.join("bases");
            write_atomic(&dir, &dir.join(&base_id), j.to_string().as_bytes())?;
            self.bases
                .insert(base_id.clone(), BaseRef { model_fp: fp, bytes: base_bytes.len() as u64 });
        }

        // Model manifest: create or extend, rejecting contradictions.
        let mut manifest = match self.models.get(name) {
            Some(m) => {
                if m.model_fp != fp {
                    return Err(RecoveryError::RegistryConflict {
                        model: name.to_string(),
                        reason: format!(
                            "published model_fp {:016x} != run's {fp:016x}",
                            m.model_fp
                        ),
                    });
                }
                if m.base != base_id {
                    return Err(RecoveryError::RegistryConflict {
                        model: name.to_string(),
                        reason: "run's base snapshot differs from the model's published base"
                            .into(),
                    });
                }
                m.clone()
            }
            None => ModelManifest {
                name: name.to_string(),
                model_fp: fp,
                base: base_id.clone(),
                versions: Vec::new(),
            },
        };
        match manifest.versions.iter().find(|v| v.version == w) {
            Some(existing) if existing.object == object_id => {
                // Idempotent re-publish (e.g. a determinism replica).
            }
            Some(_) => {
                return Err(RecoveryError::RegistryConflict {
                    model: name.to_string(),
                    reason: format!("v{w} already published with different bytes"),
                })
            }
            None => {
                manifest.versions.push(VersionRef {
                    version: w,
                    object: object_id.clone(),
                    witness,
                    payload_bytes: artifact.bytes.len() as u64,
                });
                manifest.versions.sort_by_key(|v| v.version);
                self.write_manifest(&manifest)?;
            }
        }
        self.models.insert(name.to_string(), manifest);
        Ok(PublishReport {
            model: name.to_string(),
            version: w,
            object: object_id,
            payload_bytes: artifact.bytes.len() as u64,
            base: base_id,
            base_bytes: base_bytes.len() as u64,
            base_was_new,
            object_was_new,
        })
    }

    /// Remove `name` from the namespace. Its objects stay in the pool
    /// until [`ModelRegistry::gc`] finds them unreferenced and unpinned.
    pub fn unpublish(&mut self, name: &str) -> Result<(), RecoveryError> {
        if self.models.remove(name).is_none() {
            return Err(RecoveryError::UnknownModel { model: name.to_string() });
        }
        let dir = self.root.join("models").join(name);
        fs::remove_dir_all(&dir)?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Reads / reconstruction
    // ------------------------------------------------------------------

    /// Decode `name`'s shared base into a [`ParamSet`], verifying the
    /// caller's layout matches the published fingerprint.
    pub fn base_params(
        &self,
        name: &str,
        layout: &ModelLayout,
    ) -> Result<ParamSet, RecoveryError> {
        let m = self.model(name)?;
        if m.model_fp != layout.fingerprint() {
            return Err(RecoveryError::BaseMismatch {
                model: name.to_string(),
                reason: format!(
                    "layout fingerprint {:016x} != published {:016x}",
                    layout.fingerprint(),
                    m.model_fp
                ),
            });
        }
        let bytes = self.read_object(&m.base, 0)?;
        ParamSet::from_snapshot_bytes(layout, &bytes)
            .map_err(|reason| RecoveryError::CorruptManifest { version: 0, reason })
    }

    /// Decode the folded delta published as `name@version` (base -> w).
    pub fn folded(&self, name: &str, version: u64) -> Result<SparseDelta, RecoveryError> {
        let vref = self.version_ref(name, version)?.clone();
        let bytes = self.read_object(&vref.object, version)?;
        let ckpt = DeltaCheckpoint::from_bytes(bytes).map_err(|error| {
            RecoveryError::CorruptArtifact { path: self.object_path(&vref.object), error }
        })?;
        ckpt.open().map_err(|error| RecoveryError::CorruptArtifact {
            path: self.object_path(&vref.object),
            error,
        })
    }

    /// Materialize `name@version` (base + folded chain), verified
    /// against the published witness — the registry's answer to
    /// [`DurableStore::reconstruct`].
    pub fn reconstruct(
        &self,
        layout: &ModelLayout,
        name: &str,
        version: u64,
    ) -> Result<ParamSet, RecoveryError> {
        let mut policy = self.base_params(name, layout)?;
        let delta = self.folded(name, version)?;
        apply_delta(&mut policy, &delta);
        if policy_witness(&policy) != self.version_ref(name, version)?.witness {
            return Err(RecoveryError::WitnessMismatch { version });
        }
        Ok(policy)
    }

    /// Compose the hot-swap delta `source@sv -> target@tv` from
    /// published artifacts. Both fine-tunes must share one base object
    /// (the composition is undefined otherwise — typed
    /// [`RecoveryError::BaseMismatch`]). Returns the composed delta
    /// still in registry numbering (`sv -> tv`); the runtime renumbers
    /// it onto the live actor's version line before shipping.
    pub fn compose_swap(
        &self,
        layout: &ModelLayout,
        source: (&str, u64),
        target: (&str, u64),
    ) -> Result<SparseDelta, RecoveryError> {
        let (s_name, sv) = source;
        let (t_name, tv) = target;
        let s_base = &self.model(s_name)?.base;
        let t_base = &self.model(t_name)?.base;
        if s_base != t_base {
            return Err(RecoveryError::BaseMismatch {
                model: t_name.to_string(),
                reason: format!("{s_name:?} and {t_name:?} fine-tune different base objects"),
            });
        }
        let base = self.base_params(t_name, layout)?;
        let from = self.folded(s_name, sv)?;
        let to = self.folded(t_name, tv)?;
        swap_delta(&base, &from, &to).map_err(RecoveryError::Compaction)
    }

    // ------------------------------------------------------------------
    // Pins + GC
    // ------------------------------------------------------------------

    /// Pin every object a swap composition `source -> target` reads (both
    /// folded artifacts plus the shared base) against [`ModelRegistry::gc`]
    /// until the returned guard drops. Counted: overlapping swaps over
    /// the same objects are safe.
    pub fn pin_swap(
        &self,
        source: (&str, u64),
        target: (&str, u64),
    ) -> Result<SwapPin, RecoveryError> {
        let mut ids = vec![self.model(target.0)?.base.clone()];
        ids.push(self.version_ref(source.0, source.1)?.object.clone());
        ids.push(self.version_ref(target.0, target.1)?.object.clone());
        ids.sort();
        ids.dedup();
        let mut pins = self.pins.lock().expect("registry pin map poisoned");
        for id in &ids {
            *pins.entry(id.clone()).or_insert(0) += 1;
        }
        drop(pins);
        Ok(SwapPin { pins: Arc::clone(&self.pins), ids })
    }

    /// Object ids currently held by swap pins (diagnostics/tests).
    pub fn pinned(&self) -> BTreeSet<String> {
        self.pins.lock().expect("registry pin map poisoned").keys().cloned().collect()
    }

    /// Sweep the object pool: an object survives iff some model manifest
    /// references it (as base or version artifact) **or** an outstanding
    /// [`SwapPin`] holds it. Base refs whose object became collectible
    /// are removed with it. Never touches manifests.
    pub fn gc(&mut self) -> Result<GcStats, RecoveryError> {
        let mut live: BTreeSet<String> = BTreeSet::new();
        for m in self.models.values() {
            live.insert(m.base.clone());
            for v in &m.versions {
                live.insert(v.object.clone());
            }
        }
        let pinned: BTreeSet<String> =
            self.pins.lock().expect("registry pin map poisoned").keys().cloned().collect();
        let mut stats = GcStats::default();
        for entry in fs::read_dir(self.root.join("objects"))? {
            let path = entry?.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("").to_string();
            let Some(id) = name.strip_suffix(".sprw") else { continue };
            if id.starts_with('.') {
                continue;
            }
            stats.scanned += 1;
            if live.contains(id) {
                continue;
            }
            if pinned.contains(id) {
                stats.retained_pinned += 1;
                continue;
            }
            let bytes = fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            fs::remove_file(&path)?;
            if self.bases.remove(id).is_some() {
                match fs::remove_file(self.root.join("bases").join(id)) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(e.into()),
                }
            }
            stats.collected += 1;
            stats.collected_bytes += bytes;
        }
        Ok(stats)
    }

    /// JSON rendering of the whole namespace (daemon `GET /models`, CLI
    /// `registry list`).
    pub fn to_json(&self) -> Json {
        let models: Vec<Json> = self
            .models
            .values()
            .map(|m| {
                let versions: Vec<Json> = m
                    .versions
                    .iter()
                    .map(|v| {
                        Json::obj()
                            .set("version", v.version)
                            .set("object", v.object.as_str())
                            .set("witness", hex(&v.witness))
                            .set("payload_bytes", v.payload_bytes)
                    })
                    .collect();
                Json::obj()
                    .set("name", m.name.as_str())
                    .set("model_fp", format!("{:016x}", m.model_fp))
                    .set("base", m.base.as_str())
                    .set("versions", Json::Arr(versions))
            })
            .collect();
        Json::obj()
            .set("registry", self.root.display().to_string())
            .set("models", Json::Arr(models))
    }
}

fn manifest_from_json(name: &str, raw: &str) -> Result<ModelManifest, RecoveryError> {
    let corrupt = |reason: String| RecoveryError::CorruptManifest { version: 0, reason };
    let j = Json::parse(raw)
        .map_err(|reason| corrupt(format!("model {name}: {reason}")))?;
    let model_fp = j
        .get("model_fp")
        .and_then(Json::as_str)
        .and_then(parse_u64_hex)
        .ok_or_else(|| corrupt(format!("model {name}: missing model_fp")))?;
    let base = j
        .get("base")
        .and_then(Json::as_str)
        .ok_or_else(|| corrupt(format!("model {name}: missing base")))?
        .to_string();
    let versions_json = j
        .get("versions")
        .and_then(Json::as_arr)
        .ok_or_else(|| corrupt(format!("model {name}: missing versions")))?;
    let mut versions = Vec::with_capacity(versions_json.len());
    for v in versions_json {
        let version = v
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| corrupt(format!("model {name}: version entry missing version")))?;
        let object = v
            .get("object")
            .and_then(Json::as_str)
            .ok_or_else(|| corrupt(format!("model {name}: v{version} missing object")))?
            .to_string();
        let witness = v
            .get("witness")
            .and_then(Json::as_str)
            .and_then(parse_hash)
            .ok_or_else(|| corrupt(format!("model {name}: v{version} missing witness")))?;
        let payload_bytes = v
            .get("payload_bytes")
            .and_then(Json::as_u64)
            .ok_or_else(|| corrupt(format!("model {name}: v{version} missing payload_bytes")))?;
        versions.push(VersionRef { version, object, witness, payload_bytes });
    }
    versions.sort_by_key(|v| v.version);
    Ok(ModelManifest { name: name.to_string(), model_fp, base, versions })
}

fn parse_u64_hex(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// tmp + fsync + rename in `dir`, hiding the tmp behind a dot.
fn write_atomic(dir: &Path, dest: &Path, bytes: &[u8]) -> Result<(), RecoveryError> {
    let tmp = dir.join(format!(
        ".{}.tmp",
        dest.file_name().and_then(|s| s.to_str()).unwrap_or("reg")
    ));
    write_atomic_at(&tmp, dest, bytes)
}

fn write_atomic_at(tmp: &Path, dest: &Path, bytes: &[u8]) -> Result<(), RecoveryError> {
    {
        let mut f = fs::File::create(tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(tmp, dest)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{ApplyMode, TensorDelta};
    use crate::util::{Bf16, Rng};

    fn layout() -> ModelLayout {
        ModelLayout::transformer("reg-test", 64, 16, 2, 32)
    }

    fn random_delta(
        l: &ModelLayout,
        rng: &mut Rng,
        density: f64,
        version: u64,
        base_version: u64,
    ) -> SparseDelta {
        let tensors = l
            .tensors
            .iter()
            .enumerate()
            .map(|(ti, t)| {
                let n = t.numel() as usize;
                let k = ((n as f64 * density).ceil() as usize).clamp(1, n);
                let mut idx: Vec<u64> = Vec::with_capacity(k);
                while idx.len() < k {
                    let i = rng.range(0, n) as u64;
                    if !idx.contains(&i) {
                        idx.push(i);
                    }
                }
                idx.sort_unstable();
                let vals = idx.iter().map(|_| Bf16::from_f32(rng.normal() as f32)).collect();
                TensorDelta { tensor: ti as u32, idx, vals }
            })
            .collect();
        SparseDelta {
            version,
            base_version,
            model_fp: l.fingerprint(),
            mode: ApplyMode::Assign,
            tensors,
        }
    }

    #[test]
    fn swap_delta_is_bit_exact_over_density_range() {
        let l = layout();
        let mut rng = Rng::new(0xD00D);
        for &density in &[0.001, 0.01, 0.1, 0.5] {
            let base = ParamSet::random(&l, 0.02, &mut rng);
            let fa = random_delta(&l, &mut rng, density, 3, 0);
            let fb = random_delta(&l, &mut rng, density / 2.0, 5, 0);
            let mut pa = base.clone();
            apply_delta(&mut pa, &fa);
            let mut pb = base.clone();
            apply_delta(&mut pb, &fb);
            let d = swap_delta(&base, &fa, &fb).unwrap();
            assert_eq!(d.base_version, 3);
            assert_eq!(d.version, 5);
            let mut swapped = pa.clone();
            apply_delta(&mut swapped, &d);
            assert_eq!(
                policy_witness(&swapped),
                policy_witness(&pb),
                "swap at density {density} not bit-exact"
            );
        }
    }

    #[test]
    fn swap_delta_rejects_mismatched_bases() {
        let l = layout();
        let mut rng = Rng::new(7);
        let base = ParamSet::random(&l, 0.02, &mut rng);
        let fa = random_delta(&l, &mut rng, 0.01, 3, 0);
        let mut fb = random_delta(&l, &mut rng, 0.01, 5, 1);
        assert!(matches!(
            swap_delta(&base, &fa, &fb),
            Err(MergeError::NonContiguous { .. })
        ));
        fb.base_version = 0;
        fb.model_fp ^= 1;
        assert!(matches!(swap_delta(&base, &fa, &fb), Err(MergeError::ModelMismatch)));
    }

    fn test_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("sprw-registry-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_rejects_a_run_dir_and_run_check_rejects_a_registry() {
        let dir = test_dir("layouts");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("journal.jsonl"), b"{}\n").unwrap();
        assert!(matches!(
            ModelRegistry::open(&dir),
            Err(RecoveryError::NotARegistry { .. })
        ));
        let reg_dir = test_dir("fresh");
        let reg = ModelRegistry::open(&reg_dir).unwrap();
        assert!(reg.models().is_empty());
        assert!(matches!(
            expect_run_dir(&reg_dir),
            Err(RecoveryError::NotARun { .. })
        ));
        fs::remove_dir_all(&dir).unwrap();
        fs::remove_dir_all(&reg_dir).unwrap();
    }

    #[test]
    fn unknown_lookups_are_typed() {
        let dir = test_dir("unknown");
        let reg = ModelRegistry::open(&dir).unwrap();
        assert!(matches!(
            reg.model("ghost"),
            Err(RecoveryError::UnknownModel { .. })
        ));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn model_names_are_validated() {
        assert!(valid_model_name("ft-a.v2_x"));
        assert!(!valid_model_name(""));
        assert!(!valid_model_name("../escape"));
        assert!(!valid_model_name(".hidden"));
        assert!(!valid_model_name("a/b"));
    }
}
