//! Lossless sparse delta checkpoints (paper §5.1).
//!
//! One RL step changes ~1% of parameter elements (§3). The Trainer diffs
//! consecutive bf16 policy snapshots, keeps only changed elements, and
//! encodes them as per-tensor (LEB128 gap-coded index, bf16 value) sections
//! wrapped in a versioned, hashed, immutable artifact.
//!
//! Value semantics: by default SparrowRL stores the **new bf16 bit
//! pattern** and applies it with scatter-*assign*. The paper describes
//! scatter-add of deltas; with bf16 storage `old + (new-old)` re-rounds and
//! is not always bit-exact, whereas assignment is lossless by construction
//! at identical payload size (16 bits/value). An additive mode is provided
//! for compatibility experiments (`ApplyMode::Add`).
//!
//! Round-tripping a small delta through the wire codec:
//!
//! ```
//! use sparrowrl::delta::{
//!     apply_delta, decode_delta, encode_delta, extract_delta, ApplyMode, ModelLayout, ParamSet,
//! };
//! use sparrowrl::util::{Bf16, Rng};
//!
//! let layout = ModelLayout::transformer("doc", 64, 16, 2, 32);
//! let mut rng = Rng::new(7);
//! let old = ParamSet::random(&layout, 0.02, &mut rng);
//! let mut new = old.clone();
//! new.tensors[0][3] = Bf16::from_f32(0.5); // one training "update"
//!
//! let delta = extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign);
//! let wire = encode_delta(&delta);
//! let back = decode_delta(&wire).expect("codec is lossless");
//! assert_eq!(back, delta);
//!
//! let mut actor = old.clone();
//! apply_delta(&mut actor, &back);
//! assert_eq!(actor, new, "bit-exact after scatter-assign");
//! ```

pub mod checkpoint;
pub mod encode;
pub mod extract;
pub mod layout;
pub mod naive;
pub mod registry;
pub mod store;
pub mod stream;
pub mod varint;

pub use checkpoint::{CheckpointStore, DeltaCheckpoint};
pub use registry::{
    expect_run_dir, swap_delta, GcStats, ModelManifest, ModelRegistry, PublishReport, SwapPin,
    VersionRef,
};
pub use store::{
    merge_chain, policy_witness, CompactStats, DurableStore, JournalRecord, MergeError,
    RecoveryError, ResumePoint, SeedRecord,
};
pub use encode::{decode_delta, encode_delta, DecodeError};
pub use extract::{apply_delta, extract_delta, extract_delta_parallel};
pub use layout::{ModelLayout, TensorSpec};
pub use stream::{
    DeltaStreamApplier, DeltaStreamDecoder, DeltaStreamEncoder, StagedDelta, StreamConfig,
    StreamError, StreamStats,
};

use crate::util::Bf16;

/// How delta values are applied to actor-resident parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApplyMode {
    /// Values are new bf16 bit patterns; apply by assignment (lossless).
    Assign,
    /// Values are bf16 differences; apply by addition (paper wording;
    /// bit-exactness not guaranteed under bf16 re-rounding).
    Add,
}

impl ApplyMode {
    pub fn to_u8(self) -> u8 {
        match self {
            ApplyMode::Assign => 0,
            ApplyMode::Add => 1,
        }
    }
    pub fn from_u8(x: u8) -> Option<ApplyMode> {
        match x {
            0 => Some(ApplyMode::Assign),
            1 => Some(ApplyMode::Add),
            _ => None,
        }
    }
}

/// Sparse update for one fused tensor: sorted distinct flat indices and the
/// matching values.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorDelta {
    pub tensor: u32,
    pub idx: Vec<u64>,
    pub vals: Vec<Bf16>,
}

impl TensorDelta {
    pub fn nnz(&self) -> u64 {
        debug_assert_eq!(self.idx.len(), self.vals.len());
        self.idx.len() as u64
    }
}

/// A full-model sparse delta: what one training step ships to every actor.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseDelta {
    /// Policy version this delta *produces*.
    pub version: u64,
    /// Version it must be applied on top of (acceptance predicate §5.2).
    pub base_version: u64,
    /// Fingerprint of the `ModelLayout` this delta addresses.
    pub model_fp: u64,
    pub mode: ApplyMode,
    pub tensors: Vec<TensorDelta>,
}

impl SparseDelta {
    pub fn nnz(&self) -> u64 {
        self.tensors.iter().map(|t| t.nnz()).sum()
    }

    /// Element-wise nonzero ratio rho (paper Eq. 1).
    pub fn density(&self, layout: &ModelLayout) -> f64 {
        self.nnz() as f64 / layout.total_params() as f64
    }

    /// Sanity checks: sorted distinct indices, in-bounds, matching lengths.
    pub fn validate(&self, layout: &ModelLayout) -> Result<(), String> {
        if self.model_fp != layout.fingerprint() {
            return Err("model fingerprint mismatch".into());
        }
        for t in &self.tensors {
            let spec = layout
                .tensors
                .get(t.tensor as usize)
                .ok_or_else(|| format!("tensor id {} out of range", t.tensor))?;
            if t.idx.len() != t.vals.len() {
                return Err(format!("{}: idx/vals length mismatch", spec.name));
            }
            let n = spec.numel();
            let mut prev: Option<u64> = None;
            for &i in &t.idx {
                if i >= n {
                    return Err(format!("{}: index {} >= numel {}", spec.name, i, n));
                }
                if let Some(p) = prev {
                    if i <= p {
                        return Err(format!("{}: indices not strictly increasing", spec.name));
                    }
                }
                prev = Some(i);
            }
        }
        Ok(())
    }
}

/// A model's parameters as bf16 storage, one buffer per fused tensor —
/// the actor-resident policy the deltas are applied to.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    pub tensors: Vec<Vec<Bf16>>,
}

impl ParamSet {
    pub fn zeros(layout: &ModelLayout) -> Self {
        ParamSet {
            tensors: layout
                .tensors
                .iter()
                .map(|t| vec![Bf16::ZERO; t.numel() as usize])
                .collect(),
        }
    }

    /// Gaussian init quantized to bf16 (matches the model's init scale).
    pub fn random(layout: &ModelLayout, scale: f32, rng: &mut crate::util::Rng) -> Self {
        ParamSet {
            tensors: layout
                .tensors
                .iter()
                .map(|t| {
                    (0..t.numel())
                        .map(|_| Bf16::from_f32(rng.normal() as f32 * scale))
                        .collect()
                })
                .collect(),
        }
    }

    /// Transformer-style init: Gaussian(0.02) weights, norm gains at 1.0
    /// (mirrors `python/compile/model.py::init_params`).
    pub fn transformer_init(layout: &ModelLayout, rng: &mut crate::util::Rng) -> Self {
        ParamSet {
            tensors: layout
                .tensors
                .iter()
                .map(|t| {
                    if t.name.contains("norm") {
                        vec![Bf16::from_f32(1.0); t.numel() as usize]
                    } else {
                        (0..t.numel())
                            .map(|_| Bf16::from_f32(rng.normal() as f32 * 0.02))
                            .collect()
                    }
                })
                .collect(),
        }
    }

    pub fn total_params(&self) -> u64 {
        self.tensors.iter().map(|t| t.len() as u64).sum()
    }

    /// Flatten every parameter to little-endian bf16 bytes in layout
    /// order — the full-policy snapshot wire form used to bootstrap a
    /// joining actor when the delta chain is unavailable
    /// (`rt::net::Msg::Snapshot`). O(N) bytes, the baseline the sparse
    /// chain's O(rho * k) is measured against.
    pub fn to_snapshot_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.total_params() as usize * 2);
        for t in &self.tensors {
            for v in t {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        out
    }

    /// Rebuild parameters from [`ParamSet::to_snapshot_bytes`] output.
    /// The byte count must match the layout exactly — a short or long
    /// snapshot is a protocol error, never a partial apply.
    pub fn from_snapshot_bytes(layout: &ModelLayout, bytes: &[u8]) -> Result<ParamSet, String> {
        let want = layout.tensors.iter().map(|t| t.numel()).sum::<u64>() * 2;
        if bytes.len() as u64 != want {
            return Err(format!("snapshot size {} != layout size {}", bytes.len(), want));
        }
        let mut at = 0usize;
        let mut tensors = Vec::with_capacity(layout.tensors.len());
        for spec in &layout.tensors {
            let n = spec.numel() as usize;
            let mut t = Vec::with_capacity(n);
            for i in 0..n {
                let b = [bytes[at + 2 * i], bytes[at + 2 * i + 1]];
                t.push(Bf16::from_bits(u16::from_le_bytes(b)));
            }
            at += 2 * n;
            tensors.push(t);
        }
        Ok(ParamSet { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> ModelLayout {
        ModelLayout::transformer("t", 64, 16, 2, 32)
    }

    #[test]
    fn validate_accepts_well_formed() {
        let l = layout();
        let d = SparseDelta {
            version: 2,
            base_version: 1,
            model_fp: l.fingerprint(),
            mode: ApplyMode::Assign,
            tensors: vec![TensorDelta {
                tensor: 0,
                idx: vec![0, 5, 9],
                vals: vec![Bf16::from_f32(1.0); 3],
            }],
        };
        assert!(d.validate(&l).is_ok());
        assert_eq!(d.nnz(), 3);
    }

    #[test]
    fn validate_rejects_unsorted_and_oob() {
        let l = layout();
        let mut d = SparseDelta {
            version: 2,
            base_version: 1,
            model_fp: l.fingerprint(),
            mode: ApplyMode::Assign,
            tensors: vec![TensorDelta {
                tensor: 0,
                idx: vec![5, 5],
                vals: vec![Bf16::ZERO; 2],
            }],
        };
        assert!(d.validate(&l).is_err());
        d.tensors[0].idx = vec![u64::MAX];
        d.tensors[0].vals = vec![Bf16::ZERO];
        assert!(d.validate(&l).is_err());
        d.tensors[0].tensor = 99;
        assert!(d.validate(&l).is_err());
    }

    #[test]
    fn validate_rejects_wrong_model() {
        let l = layout();
        let d = SparseDelta {
            version: 1,
            base_version: 0,
            model_fp: 0xDEAD,
            mode: ApplyMode::Assign,
            tensors: vec![],
        };
        assert!(d.validate(&l).is_err());
    }

    #[test]
    fn paramset_shapes_match_layout() {
        let l = layout();
        let p = ParamSet::zeros(&l);
        assert_eq!(p.total_params(), l.total_params());
        assert_eq!(p.tensors.len(), l.tensors.len());
    }
}
