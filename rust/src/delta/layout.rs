//! Model parameter layout: the fixed, ordered list of *fused* tensors that
//! both the L2 JAX model and the L3 coordinator agree on.
//!
//! Following §5.1, attention projections are written under fused inference
//! names (Q‖K‖V -> `qkv_proj`, Gate‖Up -> `gate_up_proj`) by stacking the
//! split blocks at deterministic offsets, so a delta addresses each fused
//! tensor through a single flat 1-D index space.

/// One fused parameter tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn new(name: &str, shape: &[usize]) -> Self {
        TensorSpec { name: name.to_string(), shape: shape.to_vec() }
    }

    pub fn numel(&self) -> u64 {
        self.shape.iter().map(|&d| d as u64).product()
    }
}

/// Ordered fused-tensor layout of a model. Tensor ids are positions in
/// `tensors`; a global flat index space concatenates tensors in order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelLayout {
    pub model_id: String,
    pub tensors: Vec<TensorSpec>,
}

impl ModelLayout {
    pub fn new(model_id: &str, tensors: Vec<TensorSpec>) -> Self {
        ModelLayout { model_id: model_id.to_string(), tensors }
    }

    /// Transformer layout mirroring the python model (model.py) exactly:
    /// embed, final_norm, norms, qkv_proj, o_proj, gate_up_proj, down_proj.
    pub fn transformer(
        model_id: &str,
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        d_ff: usize,
    ) -> Self {
        ModelLayout::new(
            model_id,
            vec![
                TensorSpec::new("embed", &[vocab, d_model]),
                TensorSpec::new("final_norm", &[d_model]),
                TensorSpec::new("norms", &[n_layers, 2, d_model]),
                // Q ‖ K ‖ V fused on the output dim (paper Fig 6).
                TensorSpec::new("qkv_proj", &[n_layers, d_model, 3 * d_model]),
                TensorSpec::new("o_proj", &[n_layers, d_model, d_model]),
                // Gate ‖ Up fused on the output dim.
                TensorSpec::new("gate_up_proj", &[n_layers, d_model, 2 * d_ff]),
                TensorSpec::new("down_proj", &[n_layers, d_ff, d_model]),
            ],
        )
    }

    pub fn total_params(&self) -> u64 {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Dense bf16 footprint in bytes (what full-weight broadcast ships).
    pub fn dense_bytes_bf16(&self) -> u64 {
        self.total_params() * 2
    }

    /// Flat offset of tensor `i` in the global index space.
    pub fn tensor_offset(&self, i: usize) -> u64 {
        self.tensors[..i].iter().map(|t| t.numel()).sum()
    }

    /// Map a global flat index to (tensor id, intra-tensor index).
    pub fn locate(&self, flat: u64) -> Option<(usize, u64)> {
        let mut off = 0u64;
        for (i, t) in self.tensors.iter().enumerate() {
            let n = t.numel();
            if flat < off + n {
                return Some((i, flat - off));
            }
            off += n;
        }
        None
    }

    pub fn tensor_id(&self, name: &str) -> Option<usize> {
        self.tensors.iter().position(|t| t.name == name)
    }

    /// Stable 64-bit id of the layout (model identity check on deltas).
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a over the textual description; stable across runs/platforms.
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        eat(self.model_id.as_bytes());
        for t in &self.tensors {
            eat(t.name.as_bytes());
            for &d in &t.shape {
                eat(&(d as u64).to_le_bytes());
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ModelLayout {
        ModelLayout::transformer("t", 256, 64, 2, 256)
    }

    #[test]
    fn transformer_param_count() {
        let l = small();
        // embed 256*64 + final_norm 64 + norms 2*2*64
        // + qkv 2*64*192 + o 2*64*64 + gate_up 2*64*512 + down 2*256*64
        let expect = 256 * 64 + 64 + 2 * 2 * 64 + 2 * 64 * 192 + 2 * 64 * 64
            + 2 * 64 * 512 + 2 * 256 * 64;
        assert_eq!(l.total_params(), expect as u64);
        assert_eq!(l.dense_bytes_bf16(), 2 * expect as u64);
    }

    #[test]
    fn offsets_partition_index_space() {
        let l = small();
        let mut off = 0;
        for i in 0..l.tensors.len() {
            assert_eq!(l.tensor_offset(i), off);
            off += l.tensors[i].numel();
        }
        assert_eq!(off, l.total_params());
    }

    #[test]
    fn locate_round_trips() {
        let l = small();
        for i in 0..l.tensors.len() {
            let off = l.tensor_offset(i);
            assert_eq!(l.locate(off), Some((i, 0)));
            assert_eq!(l.locate(off + l.tensors[i].numel() - 1), Some((i, l.tensors[i].numel() - 1)));
        }
        assert_eq!(l.locate(l.total_params()), None);
    }

    #[test]
    fn fingerprint_sensitive_to_shape() {
        let a = small();
        let b = ModelLayout::transformer("t", 256, 64, 2, 257);
        let c = ModelLayout::transformer("u", 256, 64, 2, 256);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(a.fingerprint(), small().fingerprint());
    }

    #[test]
    fn fused_tensor_names_match_paper() {
        let l = small();
        assert!(l.tensor_id("qkv_proj").is_some());
        assert!(l.tensor_id("gate_up_proj").is_some());
    }
}
