//! Delta extraction (Trainer side) and application (Actor side).
//!
//! Extraction is the per-step CPU hot path the paper reports at ~5 s for a
//! 16 GB model; see `rust/benches/encoding.rs` and EXPERIMENTS.md §Perf for
//! our measured scan throughput. Application is a flat scatter over the
//! actor-resident parameter storage (§5.1 "flat scatter-add"; we default to
//! scatter-assign for provable bit-exactness, see delta/mod.rs).

use super::{ApplyMode, ModelLayout, ParamSet, SparseDelta, TensorDelta};
use crate::util::Bf16;

/// Diff two bf16 snapshots into a sparse delta producing `version` on top
/// of `base_version`. Comparison is on bit patterns, so -0.0 vs +0.0 and
/// NaN payload changes are all captured — the delta is exactly "whatever
/// changed in storage".
pub fn extract_delta(
    layout: &ModelLayout,
    old: &ParamSet,
    new: &ParamSet,
    base_version: u64,
    version: u64,
    mode: ApplyMode,
) -> SparseDelta {
    assert_eq!(old.tensors.len(), new.tensors.len(), "snapshot arity");
    let mut tensors = Vec::new();
    for (tid, (o, n)) in old.tensors.iter().zip(&new.tensors).enumerate() {
        assert_eq!(o.len(), n.len(), "tensor {tid} length");
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        scan_changed(o, n, |i| {
            idx.push(i as u64);
            vals.push(match mode {
                ApplyMode::Assign => n[i],
                ApplyMode::Add => Bf16::from_f32(n[i].to_f32() - o[i].to_f32()),
            });
        });
        if !idx.is_empty() {
            tensors.push(TensorDelta { tensor: tid as u32, idx, vals });
        }
    }
    SparseDelta {
        version,
        base_version,
        model_fp: layout.fingerprint(),
        mode,
        tensors,
    }
}

/// Invoke `hit(i)` for every position where `old[i] != new[i]` (bitwise).
/// Word-at-a-time comparison: four bf16 lanes per u64, branch only on the
/// rare unequal word — this is what makes the dense scan ~memory-bound.
/// Shared with the fused streaming encoder (`delta/stream.rs`), which
/// calls it per chunk with an index offset.
#[inline]
pub(crate) fn scan_changed<F: FnMut(usize)>(old: &[Bf16], new: &[Bf16], mut hit: F) {
    let n = old.len();
    let words = n / 4;
    // Safety: Bf16 is a repr-transparent-sized u16; we only read.
    let (op, np) = (old.as_ptr() as *const u64, new.as_ptr() as *const u64);
    let mut i = 0usize;
    // Alignment: Vec<Bf16> is 2-byte aligned; use unaligned reads.
    while i < words {
        let (a, b) = unsafe { ((op.add(i)).read_unaligned(), (np.add(i)).read_unaligned()) };
        if a != b {
            let base = i * 4;
            let x = a ^ b;
            if x & 0x0000_0000_0000_FFFF != 0 {
                hit(base);
            }
            if x & 0x0000_0000_FFFF_0000 != 0 {
                hit(base + 1);
            }
            if x & 0x0000_FFFF_0000_0000 != 0 {
                hit(base + 2);
            }
            if x & 0xFFFF_0000_0000_0000 != 0 {
                hit(base + 3);
            }
        }
        i += 1;
    }
    for j in words * 4..n {
        if old[j].to_bits() != new[j].to_bits() {
            hit(j);
        }
    }
}

/// Parallel extraction: per-tensor scans fan out over `threads` OS
/// threads (the fused layout gives natural independent shards). Falls
/// back to the serial path for small models where spawn cost dominates.
pub fn extract_delta_parallel(
    layout: &ModelLayout,
    old: &ParamSet,
    new: &ParamSet,
    base_version: u64,
    version: u64,
    mode: ApplyMode,
    threads: usize,
) -> SparseDelta {
    let total = layout.total_params();
    if threads <= 1 || total < 4_000_000 {
        return extract_delta(layout, old, new, base_version, version, mode);
    }
    let n_tensors = old.tensors.len();
    let results: Vec<Option<TensorDelta>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n_tensors);
        for tid in 0..n_tensors {
            let (o, n) = (&old.tensors[tid], &new.tensors[tid]);
            handles.push(scope.spawn(move || {
                let mut idx = Vec::new();
                let mut vals = Vec::new();
                scan_changed(o, n, |i| {
                    idx.push(i as u64);
                    vals.push(match mode {
                        ApplyMode::Assign => n[i],
                        ApplyMode::Add => Bf16::from_f32(n[i].to_f32() - o[i].to_f32()),
                    });
                });
                (!idx.is_empty()).then_some(TensorDelta { tensor: tid as u32, idx, vals })
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    SparseDelta {
        version,
        base_version,
        model_fp: layout.fingerprint(),
        mode,
        tensors: results.into_iter().flatten().collect(),
    }
}

/// Apply a delta to actor-resident parameters in place.
///
/// Preconditions (the staged-activation protocol enforces these before
/// calling): `delta.validate(layout)` passed and the actor's active version
/// equals `delta.base_version`.
pub fn apply_delta(params: &mut ParamSet, delta: &SparseDelta) {
    for t in &delta.tensors {
        let buf = &mut params.tensors[t.tensor as usize];
        match delta.mode {
            ApplyMode::Assign => {
                for (&i, &v) in t.idx.iter().zip(&t.vals) {
                    buf[i as usize] = v;
                }
            }
            ApplyMode::Add => {
                for (&i, &v) in t.idx.iter().zip(&t.vals) {
                    let cur = buf[i as usize].to_f32();
                    buf[i as usize] = Bf16::from_f32(cur + v.to_f32());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn layout() -> ModelLayout {
        ModelLayout::transformer("t", 128, 32, 2, 64)
    }

    fn perturb(p: &ParamSet, k_per_tensor: usize, rng: &mut Rng) -> ParamSet {
        let mut q = p.clone();
        for t in &mut q.tensors {
            let n = t.len();
            for _ in 0..k_per_tensor.min(n) {
                let i = rng.range(0, n);
                // Flip to a guaranteed-different value.
                let old = t[i];
                let mut v = Bf16::from_f32(rng.normal() as f32);
                if v == old {
                    v = Bf16::from_bits(old.to_bits() ^ 1);
                }
                t[i] = v;
            }
        }
        q
    }

    #[test]
    fn identical_snapshots_give_empty_delta() {
        let l = layout();
        let mut rng = Rng::new(1);
        let p = ParamSet::random(&l, 0.02, &mut rng);
        let d = extract_delta(&l, &p, &p, 0, 1, ApplyMode::Assign);
        assert_eq!(d.nnz(), 0);
        assert!(d.tensors.is_empty());
    }

    #[test]
    fn assign_round_trip_is_bit_exact() {
        let l = layout();
        let mut rng = Rng::new(2);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let new = perturb(&old, 13, &mut rng);
        let d = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign);
        d.validate(&l).unwrap();
        let mut applied = old.clone();
        apply_delta(&mut applied, &d);
        assert_eq!(applied, new, "scatter-assign must reproduce the snapshot exactly");
    }

    #[test]
    fn density_matches_perturbation() {
        let l = layout();
        let mut rng = Rng::new(3);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let new = perturb(&old, 5, &mut rng);
        let d = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign);
        // At most 5 per tensor (collisions may reduce), never zero here.
        assert!(d.nnz() >= 1 && d.nnz() <= 5 * l.tensors.len() as u64);
        assert!(d.density(&l) < 0.05);
    }

    #[test]
    fn scan_changed_hits_every_lane_and_tail() {
        // Cover each of the 4 lanes in the word-at-a-time path + odd tail.
        for n in [1usize, 3, 4, 5, 7, 8, 9, 64, 65, 66, 67] {
            for pos in 0..n {
                let old = vec![Bf16::from_f32(1.0); n];
                let mut new = old.clone();
                new[pos] = Bf16::from_f32(2.0);
                let mut hits = Vec::new();
                scan_changed(&old, &new, |i| hits.push(i));
                assert_eq!(hits, vec![pos], "n={n} pos={pos}");
            }
        }
    }

    #[test]
    fn scan_changed_tail_handles_all_residues_mod_4() {
        // Regression: tensor lengths not divisible by 4 must scan the
        // word-path prefix AND the scalar tail with consistent indexing.
        let mut rng = Rng::new(21);
        for n in [1usize, 2, 3, 5, 6, 7, 13, 63, 66, 127, 129, 130, 131] {
            let old: Vec<Bf16> = (0..n).map(|_| Bf16::from_bits(rng.next_u64() as u16)).collect();
            let mut new = old.clone();
            let mut expect = Vec::new();
            for i in 0..n {
                if rng.chance(0.3) {
                    new[i] = Bf16::from_bits(old[i].to_bits() ^ (1 << rng.range(0, 16)));
                    expect.push(i);
                }
            }
            let mut hits = Vec::new();
            scan_changed(&old, &new, |i| hits.push(i));
            assert_eq!(hits, expect, "n={n}");
        }
    }

    #[test]
    fn scan_changed_on_unaligned_subslices() {
        // Vec<Bf16> is only 2-byte aligned; subslices at odd offsets push
        // the u64 reads fully off 8-byte alignment. read_unaligned must
        // keep results exact for every offset/length combination.
        let n = 41;
        let old: Vec<Bf16> = (0..n).map(|i| Bf16::from_bits(i as u16 * 3)).collect();
        let mut new = old.clone();
        for pos in [0usize, 7, 20, 39, 40] {
            new[pos] = Bf16::from_bits(new[pos].to_bits() ^ 0x0100);
        }
        for off in 0..8 {
            for len in [1usize, 4, 9, n - off] {
                let mut hits = Vec::new();
                scan_changed(&old[off..off + len], &new[off..off + len], |i| hits.push(i + off));
                let expect: Vec<usize> = [0usize, 7, 20, 39, 40]
                    .iter()
                    .copied()
                    .filter(|&p| p >= off && p < off + len)
                    .collect();
                assert_eq!(hits, expect, "off={off} len={len}");
            }
        }
    }

    #[test]
    fn scan_is_bitwise_signed_zero_and_nan_payloads() {
        // +0.0 vs -0.0 compare equal as floats but differ bitwise; NaN
        // payload changes compare unequal-to-everything as floats. The
        // delta must capture exactly the bit-pattern changes (mod docs:
        // "whatever changed in storage").
        let pz = Bf16::from_f32(0.0);
        let nz = Bf16::from_bits(0x8000);
        let nan_a = Bf16::from_bits(0x7FC1);
        let nan_b = Bf16::from_bits(0x7FC2);
        assert!(nan_a.is_nan() && nan_b.is_nan());
        // Odd length to cover the tail path too.
        let old = vec![pz, nan_a, pz, nan_a, pz];
        let new = vec![nz, nan_a, pz, nan_b, pz];
        let mut hits = Vec::new();
        scan_changed(&old, &new, |i| hits.push(i));
        assert_eq!(hits, vec![0, 3], "-0.0 and NaN-payload flips are changes");
        // Same NaN payload is NOT a change (bitwise-equal).
        let mut hits = Vec::new();
        scan_changed(&[nan_a], &[nan_a], |i| hits.push(i));
        assert!(hits.is_empty());
        // Full extract/apply round trip over these values stays bit-exact.
        let l = ModelLayout::new("z", vec![super::super::TensorSpec::new("w", &[5])]);
        let po = ParamSet { tensors: vec![old] };
        let pn = ParamSet { tensors: vec![new] };
        let d = extract_delta(&l, &po, &pn, 0, 1, ApplyMode::Assign);
        assert_eq!(d.nnz(), 2);
        let mut applied = po.clone();
        apply_delta(&mut applied, &d);
        assert_eq!(applied, pn);
    }

    #[test]
    fn prop_assign_round_trip_random_patterns() {
        prop::check("extract/apply assign round trip", 40, |rng| {
            let l = ModelLayout::new(
                "p",
                vec![super::super::TensorSpec::new("w", &[rng.range(1, 400)])],
            );
            let old = ParamSet::random(&l, 0.1, rng);
            let mut new = old.clone();
            let n = new.tensors[0].len();
            let flips = rng.range(0, n.min(50) + 1);
            for _ in 0..flips {
                let i = rng.range(0, n);
                new.tensors[0][i] = Bf16::from_bits(rng.next_u64() as u16);
            }
            let d = extract_delta(&l, &old, &new, 3, 4, ApplyMode::Assign);
            d.validate(&l).unwrap();
            let mut applied = old.clone();
            apply_delta(&mut applied, &d);
            // Compare bit patterns (PartialEq on Bf16 is bitwise).
            assert_eq!(applied, new);
        });
    }

    #[test]
    fn add_mode_can_rerond_but_assign_cannot() {
        // Construct the classic counterexample: old and new far apart in
        // exponent so bf16(new - old) loses bits.
        let l = ModelLayout::new("c", vec![super::super::TensorSpec::new("w", &[1])]);
        let old = ParamSet { tensors: vec![vec![Bf16::from_f32(1024.0)]] };
        let new = ParamSet { tensors: vec![vec![Bf16::from_f32(1025.0 + 1000.0)]] };
        let da = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign);
        let mut pa = old.clone();
        apply_delta(&mut pa, &da);
        assert_eq!(pa, new);
        // Additive mode is applied and *may* differ; we only require that
        // the assign path is exact (documented deviation).
        let dd = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Add);
        let mut pd = old.clone();
        apply_delta(&mut pd, &dd);
        let _ = pd; // no exactness requirement
    }
}

#[cfg(test)]
mod parallel_tests {
    use super::*;
    use crate::util::{Bf16, Rng};

    #[test]
    fn parallel_matches_serial() {
        let l = ModelLayout::transformer("p", 512, 128, 4, 512);
        let mut rng = Rng::new(7);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        for t in &mut new.tensors {
            for _ in 0..50 {
                let i = rng.range(0, t.len());
                t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0011);
            }
        }
        let serial = extract_delta(&l, &old, &new, 1, 2, ApplyMode::Assign);
        let parallel = extract_delta_parallel(&l, &old, &new, 1, 2, ApplyMode::Assign, 8);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn single_thread_falls_back() {
        let l = ModelLayout::transformer("p", 64, 16, 2, 32);
        let mut rng = Rng::new(8);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let d = extract_delta_parallel(&l, &old, &old, 0, 1, ApplyMode::Assign, 16);
        assert_eq!(d.nnz(), 0);
    }
}
