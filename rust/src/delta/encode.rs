//! Binary wire/storage format for sparse delta checkpoints.
//!
//! Layout (format version 2, all little-endian):
//!
//! ```text
//! header   magic "SPRW" | fmt u8 | mode u8 | pad u16
//!          version u64 | base_version u64 | model_fp u64 | flags u32 (0)
//! section* tensor u32 | nnz u64 | idx_bytes u64
//!          LEB128 gap-coded indices (idx_bytes)
//!          bf16 values (2*nnz bytes)
//! end      tensor = 0xFFFF_FFFF (section terminator)
//! trailer  sha256 of everything above (32 bytes)
//! ```
//!
//! Format v2 replaces v1's up-front `n_tensors` header field with a
//! section *terminator* sentinel so the byte stream is single-pass
//! producible: a streaming encoder (`delta/stream.rs`) learns how many
//! tensors changed only as the scan progresses, and with the sentinel it
//! never needs to back-patch bytes that have already been hashed and
//! shipped. `encode_delta` and `DeltaStreamEncoder` emit bit-identical
//! bytes for the same delta (asserted by tests in `stream.rs`).
//!
//! The trailing SHA-256 is the checkpoint's integrity hash (§5.1): relays
//! and actors verify it after reassembly and the Job Ledger uses it in the
//! result-acceptance predicate (§5.4).

use super::varint;
use super::{ApplyMode, SparseDelta, TensorDelta};
use crate::util::Bf16;
use sha2::{Digest, Sha256};

pub const MAGIC: [u8; 4] = *b"SPRW";
pub const FORMAT_VERSION: u8 = 2;
/// Sentinel tensor id marking the end of the section list. Real tensor ids
/// are indices into the model layout and never approach this value.
pub const SECTION_END: u32 = u32::MAX;
pub(crate) const HEADER_LEN: usize = 4 + 1 + 1 + 2 + 8 + 8 + 8 + 4;
/// Per-section fixed overhead: tensor u32 + nnz u64 + idx_bytes u64.
pub(crate) const SECTION_HEADER_LEN: usize = 4 + 8 + 8;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    Truncated,
    BadMagic,
    BadFormat(u8),
    BadMode(u8),
    HashMismatch,
    Corrupt(&'static str),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self)
    }
}

impl std::error::Error for DecodeError {}

/// Write the 36-byte header for a delta's metadata into `out`.
pub(crate) fn write_header(
    out: &mut Vec<u8>,
    mode: ApplyMode,
    version: u64,
    base_version: u64,
    model_fp: u64,
) {
    out.extend_from_slice(&MAGIC);
    out.push(FORMAT_VERSION);
    out.push(mode.to_u8());
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&base_version.to_le_bytes());
    out.extend_from_slice(&model_fp.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // flags (reserved)
}

/// Serialize a delta to its canonical byte representation (with hash).
pub fn encode_delta(d: &SparseDelta) -> Vec<u8> {
    let mut out = Vec::with_capacity(estimate_encoded_len(d));
    write_header(&mut out, d.mode, d.version, d.base_version, d.model_fp);
    for t in &d.tensors {
        let mut idx_buf = Vec::with_capacity(t.idx.len() * 2);
        varint::encode_index_gaps(&t.idx, &mut idx_buf);
        out.extend_from_slice(&t.tensor.to_le_bytes());
        out.extend_from_slice(&(t.nnz()).to_le_bytes());
        out.extend_from_slice(&(idx_buf.len() as u64).to_le_bytes());
        out.extend_from_slice(&idx_buf);
        // Bulk-copy values: Bf16 is repr(transparent) u16 and the wire
        // format is little-endian, matching every supported host.
        let val_bytes = unsafe {
            std::slice::from_raw_parts(t.vals.as_ptr() as *const u8, t.vals.len() * 2)
        };
        out.extend_from_slice(val_bytes);
    }
    out.extend_from_slice(&SECTION_END.to_le_bytes());
    let hash = Sha256::digest(&out);
    out.extend_from_slice(&hash);
    out
}

/// Upper-bound estimate used to pre-allocate the encode buffer.
pub fn estimate_encoded_len(d: &SparseDelta) -> usize {
    HEADER_LEN
        + 4 // terminator
        + 32 // sha256
        + d.tensors
            .iter()
            .map(|t| SECTION_HEADER_LEN + t.idx.len() * 10 + t.vals.len() * 2)
            .sum::<usize>()
}

/// Parse and integrity-check a canonical delta byte stream.
pub fn decode_delta(bytes: &[u8]) -> Result<SparseDelta, DecodeError> {
    if bytes.len() < HEADER_LEN + 4 + 32 {
        return Err(DecodeError::Truncated);
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 32);
    let hash = Sha256::digest(body);
    if hash.as_slice() != trailer {
        return Err(DecodeError::HashMismatch);
    }
    if body[0..4] != MAGIC {
        return Err(DecodeError::BadMagic);
    }
    if body[4] != FORMAT_VERSION {
        return Err(DecodeError::BadFormat(body[4]));
    }
    let mode = ApplyMode::from_u8(body[5]).ok_or(DecodeError::BadMode(body[5]))?;
    let mut pos = 8;
    let rd_u64 = |buf: &[u8], pos: &mut usize| -> Result<u64, DecodeError> {
        let b = buf
            .get(*pos..*pos + 8)
            .ok_or(DecodeError::Truncated)?;
        *pos += 8;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    };
    let rd_u32 = |buf: &[u8], pos: &mut usize| -> Result<u32, DecodeError> {
        let b = buf
            .get(*pos..*pos + 4)
            .ok_or(DecodeError::Truncated)?;
        *pos += 4;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    };
    let version = rd_u64(body, &mut pos)?;
    let base_version = rd_u64(body, &mut pos)?;
    let model_fp = rd_u64(body, &mut pos)?;
    let flags = rd_u32(body, &mut pos)?;
    if flags != 0 {
        return Err(DecodeError::Corrupt("unknown header flags"));
    }
    let mut tensors = Vec::new();
    loop {
        let tensor = rd_u32(body, &mut pos)?;
        if tensor == SECTION_END {
            break;
        }
        let nnz = rd_u64(body, &mut pos)? as usize;
        let idx_bytes = rd_u64(body, &mut pos)? as usize;
        let idx_end = pos.checked_add(idx_bytes).ok_or(DecodeError::Truncated)?;
        if idx_end > body.len() {
            return Err(DecodeError::Truncated);
        }
        let mut ipos = pos;
        let idx = varint::decode_index_gaps(body, &mut ipos, nnz)
            .ok_or(DecodeError::Corrupt("bad varint stream"))?;
        if ipos != idx_end {
            return Err(DecodeError::Corrupt("index section length mismatch"));
        }
        pos = idx_end;
        let val_end = pos.checked_add(nnz * 2).ok_or(DecodeError::Truncated)?;
        if val_end > body.len() {
            return Err(DecodeError::Truncated);
        }
        // Bulk-copy values (LE wire == LE host; see encode side).
        let mut vals: Vec<Bf16> = vec![Bf16::ZERO; nnz];
        unsafe {
            std::ptr::copy_nonoverlapping(
                body[pos..val_end].as_ptr(),
                vals.as_mut_ptr() as *mut u8,
                nnz * 2,
            );
        }
        pos = val_end;
        tensors.push(TensorDelta { tensor, idx, vals });
    }
    if pos != body.len() {
        return Err(DecodeError::Corrupt("trailing bytes"));
    }
    Ok(SparseDelta { version, base_version, model_fp, mode, tensors })
}

/// Integrity hash of an encoded delta (the last 32 bytes).
pub fn delta_hash(bytes: &[u8]) -> Option<[u8; 32]> {
    if bytes.len() < 32 {
        return None;
    }
    let mut h = [0u8; 32];
    h.copy_from_slice(&bytes[bytes.len() - 32..]);
    Some(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, ModelLayout, ParamSet};
    use crate::util::{prop, Rng};

    fn sample_delta(seed: u64, flips: usize) -> (ModelLayout, SparseDelta) {
        let l = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(seed);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        for t in &mut new.tensors {
            for _ in 0..flips.min(t.len()) {
                let i = rng.range(0, t.len());
                t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0101);
            }
        }
        let d = extract_delta(&l, &old, &new, 4, 5, ApplyMode::Assign);
        (l, d)
    }

    #[test]
    fn round_trip_preserves_everything() {
        let (l, d) = sample_delta(1, 9);
        let bytes = encode_delta(&d);
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, d);
        back.validate(&l).unwrap();
    }

    #[test]
    fn empty_delta_round_trips() {
        let l = ModelLayout::transformer("t", 64, 16, 2, 32);
        let d = SparseDelta {
            version: 1,
            base_version: 0,
            model_fp: l.fingerprint(),
            mode: ApplyMode::Assign,
            tensors: vec![],
        };
        let bytes = encode_delta(&d);
        assert_eq!(bytes.len(), HEADER_LEN + 4 + 32);
        assert_eq!(decode_delta(&bytes).unwrap(), d);
    }

    #[test]
    fn bitflip_anywhere_is_detected() {
        let (_, d) = sample_delta(2, 5);
        let bytes = encode_delta(&d);
        let mut rng = Rng::new(7);
        for _ in 0..40 {
            let mut corrupted = bytes.clone();
            let i = rng.range(0, corrupted.len());
            corrupted[i] ^= 1 << rng.range(0, 8);
            assert!(
                decode_delta(&corrupted).is_err(),
                "flip at byte {i} must not decode cleanly"
            );
        }
    }

    #[test]
    fn truncation_is_detected() {
        let (_, d) = sample_delta(3, 5);
        let bytes = encode_delta(&d);
        for cut in [0, 1, 10, HEADER_LEN, bytes.len() - 33, bytes.len() - 1] {
            assert!(decode_delta(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn estimate_is_upper_bound() {
        for seed in 0..5 {
            let (_, d) = sample_delta(seed, 20);
            assert!(encode_delta(&d).len() <= estimate_encoded_len(&d));
        }
    }

    #[test]
    fn prop_round_trip_arbitrary_sparse_patterns() {
        prop::check("delta codec round trip", 50, |rng| {
            let numel = rng.range(1, 5000) as u64;
            let k = rng.range(0, (numel as usize).min(300) + 1);
            let idx = prop::sparse_indices(rng, numel, k);
            let vals = (0..k).map(|_| Bf16::from_bits(rng.next_u64() as u16)).collect();
            let d = SparseDelta {
                version: rng.next_u64(),
                base_version: rng.next_u64(),
                model_fp: rng.next_u64(),
                mode: if rng.chance(0.5) { ApplyMode::Assign } else { ApplyMode::Add },
                tensors: vec![TensorDelta { tensor: 0, idx, vals }],
            };
            let bytes = encode_delta(&d);
            assert_eq!(decode_delta(&bytes).unwrap(), d);
        });
    }

    #[test]
    fn payload_reduction_at_one_percent_density() {
        // ~1% density => varint payload should be well under 2.5 bytes/nnz
        // for indices + 2 bytes/nnz values, i.e. ~4x+ smaller than dense
        // would only be at high density; against *dense bf16* the ratio at
        // rho=1% must approach ~50-80x (paper: 79x for Qwen3-8B).
        let l = ModelLayout::transformer("t", 512, 128, 4, 512);
        let mut rng = Rng::new(11);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        let total = l.total_params();
        let target = (total / 100) as usize; // 1%
        for tid in 0..new.tensors.len() {
            let n = new.tensors[tid].len();
            let share = ((n as u64 * target as u64) / total) as usize;
            let picks = prop::sparse_indices(&mut rng, n as u64, share.min(n));
            for i in picks {
                let t = &mut new.tensors[tid];
                t[i as usize] = Bf16::from_bits(t[i as usize].to_bits() ^ 0x0040);
            }
        }
        let d = extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign);
        let sparse = encode_delta(&d).len() as f64;
        let dense = l.dense_bytes_bf16() as f64;
        let ratio = dense / sparse;
        assert!(ratio > 40.0, "dense/sparse ratio {ratio:.1} too small");
    }

    #[test]
    fn v1_streams_are_rejected_as_bad_format() {
        let (_, d) = sample_delta(9, 3);
        let mut bytes = encode_delta(&d);
        bytes[4] = 1; // pretend format version 1
        // Re-hash so only the format byte is wrong.
        let body_len = bytes.len() - 32;
        let h = Sha256::digest(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&h);
        assert_eq!(decode_delta(&bytes), Err(DecodeError::BadFormat(1)));
    }
}
