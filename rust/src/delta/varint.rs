//! Unsigned LEB128 variable-length integers (paper §5.1, Figure 6).
//!
//! Sorted nonzero indices are first delta-encoded (each index replaced by
//! its gap from the predecessor) and the gaps — overwhelmingly < 128 at
//! ~1% density — are stored as LEB128: 7 payload bits per byte, high bit
//! set on all but the final byte. The paper's example: 198 = 0xC6 0x01
//! (payload 70 + (1<<7)).

/// Append the LEB128 encoding of `x` to `out`. Returns bytes written.
#[inline]
pub fn write_uleb128(out: &mut Vec<u8>, mut x: u64) -> usize {
    let mut n = 0;
    loop {
        let byte = (x & 0x7F) as u8;
        x >>= 7;
        n += 1;
        if x == 0 {
            out.push(byte);
            return n;
        }
        out.push(byte | 0x80);
    }
}

/// Decode one LEB128 value from `buf[pos..]`, advancing `pos`.
///
/// Strictly canonical: returns None on truncation, on encodings that would
/// overflow a u64 (10th byte > 1 or an 11th continuation byte), and on
/// overlong encodings (a multi-byte encoding whose final byte is zero —
/// the value had a shorter canonical form). Canonicality guarantees every
/// value has exactly one byte representation, which is what lets the
/// streaming and legacy encoders be byte-identical by construction.
#[inline]
pub fn read_uleb128(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut x: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *buf.get(*pos)?;
        *pos += 1;
        if shift == 63 && byte > 1 {
            return None; // would overflow u64
        }
        x |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            if byte == 0 && shift > 0 {
                return None; // overlong: trailing zero byte
            }
            return Some(x);
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

/// Size in bytes of the LEB128 encoding of `x`.
#[inline]
pub fn uleb128_len(x: u64) -> usize {
    if x == 0 {
        return 1;
    }
    (64 - x.leading_zeros() as usize).div_ceil(7)
}

/// Encode a *sorted, distinct* index array as first-index + gap LEB128s.
/// Panics in debug builds if the input is not strictly increasing.
pub fn encode_index_gaps(indices: &[u64], out: &mut Vec<u8>) {
    let mut prev: Option<u64> = None;
    for &i in indices {
        match prev {
            None => {
                write_uleb128(out, i);
            }
            Some(p) => {
                debug_assert!(i > p, "indices must be strictly increasing");
                write_uleb128(out, i - p);
            }
        }
        prev = Some(i);
    }
}

/// Decode `count` gap-encoded indices from `buf[pos..]`.
pub fn decode_index_gaps(buf: &[u8], pos: &mut usize, count: usize) -> Option<Vec<u64>> {
    let mut out = Vec::with_capacity(count);
    let mut acc: u64 = 0;
    for k in 0..count {
        let v = read_uleb128(buf, pos)?;
        acc = if k == 0 { v } else { acc.checked_add(v)? };
        out.push(acc);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn paper_example_198() {
        let mut buf = Vec::new();
        write_uleb128(&mut buf, 198);
        assert_eq!(buf, vec![0xC6, 0x01]);
        let mut pos = 0;
        assert_eq!(read_uleb128(&buf, &mut pos), Some(198));
        assert_eq!(pos, 2);
    }

    #[test]
    fn single_byte_below_128() {
        for x in 0..128u64 {
            let mut buf = Vec::new();
            assert_eq!(write_uleb128(&mut buf, x), 1);
            assert_eq!(buf, vec![x as u8]);
        }
    }

    #[test]
    fn boundary_lengths() {
        assert_eq!(uleb128_len(0), 1);
        assert_eq!(uleb128_len(127), 1);
        assert_eq!(uleb128_len(128), 2);
        assert_eq!(uleb128_len(16383), 2);
        assert_eq!(uleb128_len(16384), 3);
        assert_eq!(uleb128_len(u64::MAX), 10);
    }

    #[test]
    fn round_trip_extremes() {
        for &x in &[0u64, 1, 127, 128, 255, 300, 1 << 20, u64::MAX - 1, u64::MAX] {
            let mut buf = Vec::new();
            let n = write_uleb128(&mut buf, x);
            assert_eq!(n, uleb128_len(x));
            let mut pos = 0;
            assert_eq!(read_uleb128(&buf, &mut pos), Some(x), "x={x}");
            assert_eq!(pos, n);
        }
    }

    #[test]
    fn truncated_input_rejected() {
        let mut buf = Vec::new();
        write_uleb128(&mut buf, 1 << 30);
        buf.pop();
        let mut pos = 0;
        assert_eq!(read_uleb128(&buf, &mut pos), None);
    }

    #[test]
    fn overflow_rejected() {
        // 11 continuation bytes can never be a valid u64.
        let buf = vec![0x80u8; 10];
        let mut pos = 0;
        assert_eq!(read_uleb128(&buf, &mut pos), None);
        // 2^64 exactly (10 bytes, final byte 2) overflows.
        let buf = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x02];
        let mut pos = 0;
        assert_eq!(read_uleb128(&buf, &mut pos), None);
    }

    #[test]
    fn u64_max_is_exactly_ten_bytes_and_round_trips() {
        let mut buf = Vec::new();
        assert_eq!(write_uleb128(&mut buf, u64::MAX), 10);
        assert_eq!(buf, vec![0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0x01]);
        let mut pos = 0;
        assert_eq!(read_uleb128(&buf, &mut pos), Some(u64::MAX));
        assert_eq!(pos, 10);
    }

    #[test]
    fn overlong_encodings_rejected() {
        // 0 as two bytes, 1 as two bytes, 127 as two bytes: all non-canonical.
        for buf in [[0x80u8, 0x00], [0x81, 0x00], [0xFF, 0x00]] {
            let mut pos = 0;
            assert_eq!(read_uleb128(&buf, &mut pos), None, "{buf:02x?}");
        }
        // Ten-byte overlong zero-extension of a small value.
        let buf = [0x81, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x00];
        let mut pos = 0;
        assert_eq!(read_uleb128(&buf, &mut pos), None);
    }

    #[test]
    fn truncation_mid_value_at_every_length() {
        for &x in &[128u64, 16384, 1 << 21, 1 << 42, u64::MAX] {
            let mut buf = Vec::new();
            write_uleb128(&mut buf, x);
            for cut in 0..buf.len() {
                let mut pos = 0;
                assert_eq!(read_uleb128(&buf[..cut], &mut pos), None, "x={x} cut={cut}");
            }
        }
    }

    #[test]
    fn every_canonical_two_byte_value_accepted() {
        // Exhaustive over the 2-byte range boundary: 128..=16383.
        for x in 128u64..=16383 {
            let mut buf = Vec::new();
            assert_eq!(write_uleb128(&mut buf, x), 2);
            let mut pos = 0;
            assert_eq!(read_uleb128(&buf, &mut pos), Some(x));
        }
    }

    #[test]
    fn prop_round_trip_random_u64() {
        prop::check("uleb128 round trip", 200, |rng| {
            // Mix uniform and low-magnitude values (gap-like distribution).
            let x = if rng.chance(0.5) { rng.below(256) } else { rng.next_u64() };
            let mut buf = Vec::new();
            write_uleb128(&mut buf, x);
            let mut pos = 0;
            assert_eq!(read_uleb128(&buf, &mut pos), Some(x));
            assert_eq!(pos, buf.len());
        });
    }

    #[test]
    fn prop_gap_encoding_round_trip() {
        prop::check("index gap round trip", 100, |rng| {
            let n = rng.range(1, 100_000) as u64;
            let k = rng.range(0, (n as usize).min(500) + 1);
            let idx = prop::sparse_indices(rng, n, k);
            let mut buf = Vec::new();
            encode_index_gaps(&idx, &mut buf);
            let mut pos = 0;
            let dec = decode_index_gaps(&buf, &mut pos, k).unwrap();
            assert_eq!(dec, idx);
            assert_eq!(pos, buf.len());
        });
    }

    #[test]
    fn gap_encoding_much_smaller_than_fixed_width_at_1pct() {
        // At ~1% density mean gap is ~100 < 128, so ~1 byte per index
        // versus 4 bytes for int32 — the paper's Figure 10 claim.
        let mut rng = crate::util::Rng::new(17);
        let n = 1_000_000u64;
        let idx = prop::sparse_indices(&mut rng, n, 10_000);
        let mut buf = Vec::new();
        encode_index_gaps(&idx, &mut buf);
        let fixed = idx.len() * 4;
        assert!(
            buf.len() * 2 < fixed,
            "varint {} vs int32 {}",
            buf.len(),
            fixed
        );
    }
}
