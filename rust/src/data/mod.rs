//! Synthetic verifiable-reasoning workload (stand-in for GSM8K / MATH /
//! DeepScaleR — DESIGN.md §3): multi-digit addition posed as a token
//! sequence with an exactly checkable answer, which is all GRPO-family
//! algorithms need (a prompt distribution and a verifiable reward).
//!
//! Vocabulary (model vocab is always >= 16):
//!   0 PAD · 1 BOS · 2 EOS · 3 '+' · 4 '=' · 5..14 digits 0-9

use crate::util::Rng;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const PLUS: i32 = 3;
pub const EQ: i32 = 4;
pub const DIGIT0: i32 = 5;

/// Difficulty presets named after the paper's benchmarks: operand digit
/// counts (GSM8K-like = 2-digit, MATH-like = 3-digit, DeepScaleR-like =
/// 4-digit, longer rollouts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Benchmark {
    Gsm8k,
    Math,
    DeepScaleR,
}

impl Benchmark {
    pub fn digits(self) -> u32 {
        match self {
            Benchmark::Gsm8k => 2,
            Benchmark::Math => 3,
            Benchmark::DeepScaleR => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Benchmark::Gsm8k => "GSM8K",
            Benchmark::Math => "MATH",
            Benchmark::DeepScaleR => "DeepScaleR",
        }
    }

    pub fn all() -> [Benchmark; 3] {
        [Benchmark::Gsm8k, Benchmark::Math, Benchmark::DeepScaleR]
    }

    pub fn parse(s: &str) -> Option<Benchmark> {
        match s.to_ascii_lowercase().as_str() {
            "gsm8k" => Some(Benchmark::Gsm8k),
            "math" => Some(Benchmark::Math),
            "deepscaler" => Some(Benchmark::DeepScaleR),
            _ => None,
        }
    }
}

/// One task instance: `a + b = ?`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Task {
    pub id: u64,
    pub a: u64,
    pub b: u64,
}

impl Task {
    /// Deterministic task for a prompt id (the ledger hands out ids; both
    /// trainer and actors can reconstruct the task locally).
    pub fn from_prompt_id(id: u64, bench: Benchmark) -> Task {
        let mut rng = Rng::new(id ^ 0x5EED_5EED);
        let hi = 10u64.pow(bench.digits());
        Task { id, a: rng.below(hi), b: rng.below(hi) }
    }

    pub fn answer(&self) -> u64 {
        self.a + self.b
    }

    /// Prompt tokens: BOS digits(a) '+' digits(b) '='.
    pub fn prompt_tokens(&self) -> Vec<i32> {
        let mut t = vec![BOS];
        t.extend(digit_tokens(self.a));
        t.push(PLUS);
        t.extend(digit_tokens(self.b));
        t.push(EQ);
        t
    }

    /// Gold completion: digits of the sum then EOS.
    pub fn answer_tokens(&self) -> Vec<i32> {
        let mut t = digit_tokens(self.answer());
        t.push(EOS);
        t
    }

    /// Reward for a generated completion (tokens after '='): 1.0 for an
    /// exact match (digits + EOS), else 0.1 per correct leading token,
    /// capped below 1.0 — partial credit keeps early training off a
    /// zero-gradient plateau.
    pub fn reward(&self, generated: &[i32]) -> f32 {
        let gold = self.answer_tokens();
        let upto_eos: Vec<i32> = generated
            .iter()
            .copied()
            .take_while(|&t| t != PAD)
            .take(gold.len() + 4)
            .collect();
        if upto_eos == gold {
            return 1.0;
        }
        let correct = gold
            .iter()
            .zip(upto_eos.iter())
            .take_while(|(g, o)| g == o)
            .count();
        (0.1 * correct as f32).min(0.9)
    }
}

pub fn digit_tokens(mut x: u64) -> Vec<i32> {
    if x == 0 {
        return vec![DIGIT0];
    }
    let mut digits = Vec::new();
    while x > 0 {
        digits.push(DIGIT0 + (x % 10) as i32);
        x /= 10;
    }
    digits.reverse();
    digits
}

/// Build a fixed-shape [batch, seq] token matrix + generation mask for the
/// train-step artifact from (prompt, completion) pairs.
pub struct PackedBatch {
    pub tokens: Vec<i32>,
    pub gen_mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

pub fn pack_batch(
    pairs: &[(Vec<i32>, Vec<i32>)],
    batch: usize,
    seq: usize,
) -> PackedBatch {
    assert!(pairs.len() <= batch, "{} > {batch}", pairs.len());
    let mut tokens = vec![PAD; batch * seq];
    let mut gen_mask = vec![0.0f32; batch * seq];
    for (r, (prompt, completion)) in pairs.iter().enumerate() {
        let mut col = 0;
        for &t in prompt.iter().take(seq) {
            tokens[r * seq + col] = t;
            col += 1;
        }
        for &t in completion.iter() {
            if col >= seq {
                break;
            }
            tokens[r * seq + col] = t;
            gen_mask[r * seq + col] = 1.0;
            col += 1;
        }
    }
    PackedBatch { tokens, gen_mask, batch, seq }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_reconstruction_is_deterministic() {
        let a = Task::from_prompt_id(42, Benchmark::Gsm8k);
        let b = Task::from_prompt_id(42, Benchmark::Gsm8k);
        assert_eq!(a, b);
        let c = Task::from_prompt_id(43, Benchmark::Gsm8k);
        assert_ne!(a, c);
    }

    #[test]
    fn digit_tokenization() {
        assert_eq!(digit_tokens(0), vec![DIGIT0]);
        assert_eq!(digit_tokens(7), vec![DIGIT0 + 7]);
        assert_eq!(digit_tokens(120), vec![DIGIT0 + 1, DIGIT0 + 2, DIGIT0]);
    }

    #[test]
    fn prompt_and_answer_structure() {
        let t = Task { id: 0, a: 12, b: 34 };
        assert_eq!(
            t.prompt_tokens(),
            vec![BOS, DIGIT0 + 1, DIGIT0 + 2, PLUS, DIGIT0 + 3, DIGIT0 + 4, EQ]
        );
        assert_eq!(t.answer_tokens(), vec![DIGIT0 + 4, DIGIT0 + 6, EOS]);
    }

    #[test]
    fn reward_exact_partial_and_zero() {
        let t = Task { id: 0, a: 12, b: 34 }; // 46
        let gold = t.answer_tokens();
        assert_eq!(t.reward(&gold), 1.0);
        // Correct first digit only.
        let partial = vec![DIGIT0 + 4, DIGIT0 + 9, EOS];
        assert!((t.reward(&partial) - 0.1).abs() < 1e-6);
        // Nothing right.
        assert_eq!(t.reward(&[DIGIT0 + 9]), 0.0);
        // Trailing garbage after a full match is not exact.
        let mut too_long = gold.clone();
        too_long.push(DIGIT0);
        assert!(t.reward(&too_long) < 1.0);
    }

    #[test]
    fn benchmark_difficulty_scales_operands() {
        for bench in Benchmark::all() {
            let hi = 10u64.pow(bench.digits());
            for id in 0..50 {
                let t = Task::from_prompt_id(id, bench);
                assert!(t.a < hi && t.b < hi);
            }
        }
    }

    #[test]
    fn pack_batch_layout() {
        let t = Task { id: 0, a: 3, b: 4 };
        let pb = pack_batch(
            &[(t.prompt_tokens(), t.answer_tokens())],
            2,
            16,
        );
        assert_eq!(pb.tokens.len(), 32);
        assert_eq!(pb.tokens[0], BOS);
        // Mask zero on prompt, one on completion.
        let p_len = t.prompt_tokens().len();
        assert_eq!(pb.gen_mask[p_len - 1], 0.0);
        assert_eq!(pb.gen_mask[p_len], 1.0);
        // Second row all padding.
        assert!(pb.tokens[16..].iter().all(|&x| x == PAD));
        assert!(pb.gen_mask[16..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pack_batch_truncates_long_sequences() {
        let prompt = vec![BOS; 10];
        let completion = vec![DIGIT0; 20];
        let pb = pack_batch(&[(prompt, completion)], 1, 16);
        assert_eq!(pb.tokens.len(), 16);
        assert_eq!(pb.gen_mask.iter().filter(|&&m| m > 0.0).count(), 6);
    }
}
