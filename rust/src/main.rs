//! SparrowRL launcher CLI.
//!
//! ```text
//! sparrowrl exp <id> [--flags]   reproduce a paper table/figure (or 'all')
//! sparrowrl train [--flags]      run the real RL loop on PJRT artifacts
//! sparrowrl sim [--flags]        one simulated geo-distributed run
//! sparrowrl list                 list experiments and models
//! ```

use sparrowrl::config;
use sparrowrl::data::Benchmark;
use sparrowrl::exp;
use sparrowrl::rt::{run_local_mode, ExecMode, LocalRunConfig};
use sparrowrl::sim::driver::{run as sim_run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::trainer::Algorithm;
use sparrowrl::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage:\n  sparrowrl exp <{}|all> [--flags]\n  sparrowrl train [--model sparrow-xs] \
         [--steps N] [--sft-steps N] [--algorithm grpo|rloo|opo] [--lr-rl X] [--actors N] [--seed S] [--pipelined] \
         [--transport inproc|sim|tcp] [--tcp-streams N] [--tcp-bps BITS] [--deterministic] [--wan wan-1..wan-4] [--gantt]\n  \
         sparrowrl sim [--model qwen3-8b] [--system sparrow|full|ms|ideal] [--bench gsm8k|math|deepscaler] [--steps N]\n  \
         sparrowrl list",
        exp::ALL.join("|")
    );
    std::process::exit(2);
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "exp" => {
            let Some(id) = args.positional.get(1).map(|s| s.to_string()) else { usage() };
            exp::run(&id, &args)
        }
        "train" => cmd_train(&args),
        "sim" => cmd_sim(&args),
        "list" => {
            println!("experiments: {}", exp::ALL.join(", "));
            println!("runnable models: {}", config::runnable_models().join(", "));
            println!("analytic models: {}", config::paper_models().join(", "));
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "sparrow-xs");
    let mut cfg = LocalRunConfig::quick(&model);
    cfg.steps = args.parse_or("steps", 10u64);
    cfg.sft_steps = args.parse_or("sft-steps", 50u64);
    cfg.lr_sft = args.parse_or("lr-sft", 5e-3f32);
    cfg.lr_rl = args.parse_or("lr-rl", 1e-6f32);
    cfg.n_actors = args.parse_or("actors", 2usize);
    cfg.seed = args.parse_or("seed", 0u64);
    cfg.max_new_tokens = args.parse_or("max-new", 8usize);
    cfg.algorithm = Algorithm::parse(&args.str_or("algorithm", "grpo"))
        .ok_or_else(|| anyhow::anyhow!("bad --algorithm"))?;
    cfg.bench = Benchmark::parse(&args.str_or("bench", "gsm8k"))
        .ok_or_else(|| anyhow::anyhow!("bad --bench"))?;
    cfg.verbose = true;
    cfg.deterministic = args.flag("deterministic");
    let mut mode = if args.flag("pipelined") { ExecMode::Pipelined } else { ExecMode::Sequential };
    // Multi-region distribution: group the actors per a WAN preset
    // (implies --pipelined, since the sequential reference has no
    // distribution tree).
    let wan = args.str_or("wan", "");
    let preset = if wan.is_empty() {
        None
    } else {
        if args.get("actors").is_some() {
            anyhow::bail!("--wan sets the actor count from the preset; drop --actors");
        }
        let p = config::wan_preset(&wan)
            .ok_or_else(|| anyhow::anyhow!("unknown WAN preset {wan} (wan-1..wan-4)"))?;
        cfg.n_actors = p.n_actors();
        mode = ExecMode::Pipelined;
        Some(p)
    };
    // Transport backend: how hub↔actor traffic travels in the pipelined
    // executor. All three run the identical executor code path.
    match args.str_or("transport", "inproc").as_str() {
        // In-process mailboxes; a WAN preset becomes relay routing
        // (hub -> regional relay worker -> peers).
        "inproc" => {
            if let Some(p) = &preset {
                let plan = sparrowrl::transport::DistributionPlan::from_preset(p, 1 << 20);
                cfg.distribution = Some(sparrowrl::rt::DistributionSpec::from_plan(&plan));
                println!(
                    "WAN preset {}: {} regions, {} actors, relays {:?}",
                    p.name,
                    p.regions.len(),
                    plan.n_actors(),
                    plan.legs.iter().map(|l| l.relay).collect::<Vec<_>>(),
                );
            }
        }
        // Netsim-modeled WAN: the transport owns the relay tree and the
        // cross-stripe arrival reordering.
        "sim" => {
            mode = ExecMode::Pipelined;
            let net = match &preset {
                Some(p) => sparrowrl::transport::SimNetConfig::from_preset(p, cfg.seed),
                None => sparrowrl::transport::SimNetConfig::single_region(
                    cfg.n_actors,
                    sparrowrl::netsim::Link::from_profile(&config::regions::CANADA),
                    4,
                    cfg.seed,
                ),
            };
            println!(
                "sim transport: {} region(s), stripes {:?}",
                net.n_regions(),
                net.streams
            );
            cfg.transport = sparrowrl::rt::TransportKind::Sim(net);
        }
        // Real loopback sockets with striped, optionally throttled
        // segment push.
        "tcp" => {
            mode = ExecMode::Pipelined;
            if preset.is_some() {
                anyhow::bail!(
                    "--transport tcp streams hub→actor directly; combine --wan with --transport sim"
                );
            }
            let tc = sparrowrl::transport::TcpConfig {
                streams: args.parse_or("tcp-streams", 2usize),
                bits_per_s: args.get("tcp-bps").and_then(|s| s.parse::<f64>().ok()),
                kill: None,
            };
            println!(
                "tcp transport: {} stream(s)/actor over loopback{}",
                tc.streams,
                tc.bits_per_s
                    .map(|b| format!(", throttled to {:.0} Mbit/s", b / 1e6))
                    .unwrap_or_default(),
            );
            cfg.transport = sparrowrl::rt::TransportKind::Tcp(tc);
        }
        other => anyhow::bail!("unknown --transport {other} (inproc|sim|tcp)"),
    }
    println!(
        "training {model} with {} on {} ({} actors, {} SFT + {} RL steps, {} executor, {} transport)",
        cfg.algorithm.name(),
        cfg.bench.name(),
        cfg.n_actors,
        cfg.sft_steps,
        cfg.steps,
        mode.name(),
        cfg.transport.name(),
    );
    let report = run_local_mode(&cfg, mode)?;
    println!(
        "\ndone: {} versions, mean rho {:.3}%, wall {:.1}s, hidden sync {:.0}%",
        report.final_version,
        report.mean_rho() * 100.0,
        report.wall_s,
        report.timeline.overlap_ratio(
            "trainer",
            &[sparrowrl::metrics::SpanKind::Train, sparrowrl::metrics::SpanKind::Extract],
        ) * 100.0,
    );
    // The cross-backend equivalence witness: identical runs (same seed,
    // --deterministic) print the same digest on every transport.
    if let Some(last) = report.steps.last() {
        let hex: String = last.policy_checksum.iter().map(|b| format!("{b:02x}")).collect();
        println!("final policy checksum: {hex}");
    }
    if report.failovers > 0 {
        println!(
            "failovers: {} actor(s) lost, {} prompt(s) requeued to survivors",
            report.failovers, report.requeued_prompts,
        );
    }
    if args.flag("gantt") {
        print!("{}", report.timeline.ascii_gantt(100));
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let model = config::model(&args.str_or("model", "qwen3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let system = match args.str_or("system", "sparrow").as_str() {
        "sparrow" => System::Sparrow,
        "full" => System::PrimeRlFull,
        "ms" => System::PrimeRlMultiStream,
        "ideal" => System::IdealSingleDc,
        other => anyhow::bail!("unknown system {other}"),
    };
    let bench = Benchmark::parse(&args.str_or("bench", "gsm8k"))
        .ok_or_else(|| anyhow::anyhow!("bad --bench"))?;
    let n = args.parse_or("actors", 8usize);
    let region = config::regions::by_name(&args.str_or("region", "canada"))
        .ok_or_else(|| anyhow::anyhow!("unknown region"))?;
    let fleet = vec![RegionSpec::new(region, vec![config::GpuClass::A100; n])];
    let mut cfg = SimConfig::paper_testbed(model, bench, system, fleet);
    cfg.steps = args.parse_or("steps", 7u64);
    cfg.streams = args.parse_or("streams", 4usize);
    let r = sim_run(&cfg);
    println!(
        "{}: {:.0} tokens/s, avg step {:.1}s, avg transfer {:.2}s, payload {}",
        r.system.name(),
        r.throughput(),
        r.avg_step_time(),
        r.avg_transfer_time(),
        sparrowrl::util::fmt_bytes(r.payload_bytes()),
    );
    if args.flag("gantt") {
        print!("{}", r.timeline.ascii_gantt(100));
    }
    Ok(())
}
