//! SparrowRL launcher CLI.
//!
//! ```text
//! sparrowrl exp <id> [--flags]        reproduce a paper table/figure (or 'all')
//! sparrowrl train [--flags]           run the real RL loop on PJRT artifacts
//! sparrowrl serve [--flags]           multi-session control-plane daemon (sparrowrld)
//! sparrowrl sim [--flags]             one simulated geo-distributed run
//! sparrowrl bench run|compare|list|promote  scenario harness + regression gate
//! sparrowrl reconstruct [--flags]     rebuild a policy from a durable store or registry
//! sparrowrl registry list|publish|gc  multi-run model registry over shared base objects
//! sparrowrl list                      list experiments and models
//! ```

use sparrowrl::config;
use sparrowrl::data::Benchmark;
use sparrowrl::exp;
use sparrowrl::rt::BootstrapKind;
use sparrowrl::session::{Backend, Event, RunSpec, Session};
use sparrowrl::sim::driver::{run as sim_run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::trainer::Algorithm;
use sparrowrl::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage:\n  sparrowrl exp <{}|all> [--flags]\n  sparrowrl train [--model sparrow-xs] \
         [--steps N] [--sft-steps N] [--algorithm grpo|rloo|opo] [--lr-rl X] [--actors N] [--seed S] [--pipelined] \
         [--transport inproc|sim|tcp] [--tcp-streams N] [--tcp-bps BITS] [--deterministic] [--wan wan-1..wan-4] [--gantt]\n    \
         [--fault-script join:A@V[:snapshot],leave:A@V,crash:A@V,stall:A@V,preempt:A@V[:warn=MS],...] [--autoscale] [--lease-sweep-ms MS]\n    \
         [--persist-dir DIR] [--resume]\n  \
         sparrowrl reconstruct --persist-dir DIR [--model sparrow-xs] [--version V] [--compact]\n  \
         sparrowrl reconstruct --registry DIR --model NAME [--version V] [--layout sparrow-xs]\n  \
         sparrowrl registry list --registry DIR\n  \
         sparrowrl registry publish --registry DIR --persist-dir RUN [--name NAME] [--model sparrow-xs] [--version V]\n  \
         sparrowrl registry gc --registry DIR\n  \
         sparrowrl serve [--addr HOST:PORT] [--max-sessions N] [--actor-pool N] [--registry DIR]\n    \
         [--alert-overlap-floor X] [--alert-tpd-floor X] [--alert-payload-ceiling BYTES]\n  \
         sparrowrl sim [--model qwen3-8b] [--system sparrow|full|ms|ideal] [--bench gsm8k|math|deepscaler] [--steps N]\n  \
         sparrowrl bench run [--suite smoke|full] [--file scenarios.json] [--out FILE]\n  \
         sparrowrl bench compare OLD NEW [--threshold PCT]\n  \
         sparrowrl bench list [--suite NAME] [--file scenarios.json]\n  \
         sparrowrl bench promote ARTIFACT [--baseline PATH]\n  \
         sparrowrl list",
        exp::ALL.join("|")
    );
    std::process::exit(2);
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "exp" => {
            let Some(id) = args.positional.get(1).map(|s| s.to_string()) else { usage() };
            exp::run(&id, &args)
        }
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "sim" => cmd_sim(&args),
        "bench" => cmd_bench(&args),
        "reconstruct" => cmd_reconstruct(&args),
        "registry" => cmd_registry(&args),
        "list" => {
            println!("experiments: {}", exp::ALL.join(", "));
            println!("runnable models: {}", config::runnable_models().join(", "));
            println!("analytic models: {}", config::paper_models().join(", "));
            println!("transports: {}", Backend::NAMES.join(", "));
            println!("wan presets: {}", config::WAN_PRESET_NAMES.join(", "));
            println!("bench suites: {}", sparrowrl::bench::SUITE_NAMES.join(", "));
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `train` flags into a [`RunSpec`]. All cross-field legality
/// rules (wan↔actors, wan↔tcp, transport→pipelined coercions, ...) live
/// in `RunSpec::build`, not here — this is string parsing only.
fn train_spec(args: &Args) -> anyhow::Result<RunSpec> {
    let model = args.str_or("model", "sparrow-xs");
    let mut spec = RunSpec::model(&model)
        .steps(args.parse_or("steps", 10u64))
        .sft_steps(args.parse_or("sft-steps", 50u64))
        .lr_sft(args.parse_or("lr-sft", 5e-3f32))
        .lr_rl(args.parse_or("lr-rl", 1e-6f32))
        .seed(args.parse_or("seed", 0u64))
        .max_new_tokens(args.parse_or("max-new", 8usize))
        .algorithm(
            Algorithm::parse(&args.str_or("algorithm", "grpo"))
                .ok_or_else(|| anyhow::anyhow!("bad --algorithm"))?,
        )
        .bench(
            Benchmark::parse(&args.str_or("bench", "gsm8k"))
                .ok_or_else(|| anyhow::anyhow!("bad --bench"))?,
        );
    if args.get("actors").is_some() {
        spec = spec.actors(args.parse_or("actors", 2usize));
    }
    if args.flag("pipelined") {
        spec = spec.pipelined();
    }
    if args.flag("deterministic") {
        spec = spec.deterministic();
    }
    let wan = args.str_or("wan", "");
    if !wan.is_empty() {
        spec = spec.wan(&wan);
    }
    if args.flag("autoscale") {
        spec = spec.autoscale();
    }
    if args.get("lease-sweep-ms").is_some() {
        spec = spec.lease_sweep_ms(args.parse_or("lease-sweep-ms", 25u64));
    }
    let pdir = args.str_or("persist-dir", "");
    if !pdir.is_empty() {
        spec = spec.persist_dir(pdir);
    }
    if args.flag("resume") {
        spec = spec.resume();
    }
    let tname = args.str_or("transport", "inproc");
    let mut backend = Backend::parse(&tname)
        .ok_or_else(|| anyhow::anyhow!("unknown --transport {tname} (inproc|sim|tcp)"))?;
    if let Backend::Tcp(tc) = &mut backend {
        tc.streams = args.parse_or("tcp-streams", 2usize);
        tc.bits_per_s = args.get("tcp-bps").and_then(|s| s.parse::<f64>().ok());
    }
    let script = args.str_or("fault-script", "");
    if !script.is_empty() {
        let (spec2, kills) = apply_fault_script(spec, &script)?;
        spec = spec2;
        if !kills.is_empty() {
            let Backend::Tcp(tc) = &mut backend else {
                anyhow::bail!(
                    "crash/stall/preempt fault injection needs --transport tcp \
                     (join/leave also run on inproc)"
                );
            };
            tc.kills = kills;
        }
    }
    Ok(spec.transport(backend))
}

/// Parse one `--fault-script` into membership scripting on the spec plus
/// Tcp kill injections. Entries are comma-separated:
/// `join:A@V` (delta-chain bootstrap) / `join:A@V:snapshot`,
/// `leave:A@V`, `crash:A@V`, `stall:A@V`, `preempt:A@V:warn=MS`.
fn apply_fault_script(
    mut spec: RunSpec,
    script: &str,
) -> anyhow::Result<(RunSpec, Vec<sparrowrl::transport::KillSpec>)> {
    use sparrowrl::transport::{KillMode, KillSpec};
    fn actor_at(s: &str) -> anyhow::Result<(u32, u64)> {
        let (a, v) = s
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault-script entry needs ACTOR@VERSION, got {s:?}"))?;
        Ok((a.trim().parse()?, v.trim().parse()?))
    }
    let mut kills = Vec::new();
    for entry in script.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':');
        let kind = parts.next().unwrap_or("");
        let target = parts.next().ok_or_else(|| {
            anyhow::anyhow!("fault-script entry {entry:?} needs KIND:ACTOR@VERSION")
        })?;
        let opt = parts.next();
        let (actor, at_version) = actor_at(target)?;
        match (kind, opt) {
            ("join", None) => spec = spec.join_at(actor, at_version, BootstrapKind::DeltaChain),
            ("join", Some("snapshot")) => {
                spec = spec.join_at(actor, at_version, BootstrapKind::Snapshot)
            }
            ("join", Some("delta-chain")) => {
                spec = spec.join_at(actor, at_version, BootstrapKind::DeltaChain)
            }
            ("leave", None) => spec = spec.leave_at(actor, at_version),
            ("crash", None) => kills.push(KillSpec { actor, at_version, mode: KillMode::Crash }),
            ("stall", None) => kills.push(KillSpec { actor, at_version, mode: KillMode::Stall }),
            ("preempt", warn) => {
                let warn_ms = match warn {
                    None => 0,
                    Some(w) => w
                        .strip_prefix("warn=")
                        .and_then(|ms| ms.trim_end_matches("ms").parse().ok())
                        .ok_or_else(|| {
                            anyhow::anyhow!("preempt option must be warn=MS, got {w:?}")
                        })?,
                };
                kills.push(KillSpec { actor, at_version, mode: KillMode::Preempt { warn_ms } });
            }
            _ => anyhow::bail!(
                "unknown fault-script entry {entry:?} \
                 (join|leave|crash|stall|preempt, e.g. preempt:1@3:warn=500)"
            ),
        }
    }
    Ok((spec, kills))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let plan = train_spec(args)?.build()?;
    for note in plan.notes() {
        println!("note: {note}");
    }
    let cfg = plan.config();
    println!(
        "training {} with {} on {} ({} actors, {} SFT + {} RL steps, {} executor, {} transport)",
        cfg.model,
        cfg.algorithm.name(),
        cfg.bench.name(),
        cfg.n_actors,
        cfg.sft_steps,
        cfg.steps,
        plan.mode().name(),
        cfg.transport.name(),
    );
    // The CLI is just one subscriber of the session's typed event
    // stream: per-step lines, failover notices, and the final report all
    // come out of the same events a dashboard would consume.
    let mut session = Session::start(&plan)?;
    let report = loop {
        match session.recv() {
            Some(Event::StepCompleted(log)) => println!("{}", log.progress_line()),
            Some(Event::Failover { actor, requeued, reason }) => {
                eprintln!("actor {actor} lost ({reason}); {requeued} prompt(s) requeued to survivors")
            }
            Some(Event::Joined { actor, version, bootstrap, bytes }) => {
                println!(
                    "actor {actor} joined at v{version} ({} bootstrap, {})",
                    bootstrap.name(),
                    sparrowrl::util::fmt_bytes(bytes),
                )
            }
            Some(Event::Draining { actor, requeued }) => {
                println!("actor {actor} drained gracefully ({requeued} prompt(s) handed back)")
            }
            Some(Event::Preempted { actor }) => {
                eprintln!("actor {actor} received a spot-preemption warning; draining")
            }
            Some(Event::Swapped { actor, model, version, bytes }) => {
                println!(
                    "actor {actor} hot-swapped to {model}@v{version} ({} on the wire)",
                    sparrowrl::util::fmt_bytes(bytes),
                )
            }
            Some(Event::Autoscale { version, decision }) => {
                println!(
                    "autoscale @v{version}: {} (marginal {:.0} tok/$, reserve line {:.0})",
                    decision.name(),
                    decision.marginal_tpd(),
                    decision.reserve_line(),
                )
            }
            Some(Event::Finished(report)) => break report,
            // Warmup progress and per-version stream/commit events are
            // summarized by the step line; skip them here.
            Some(Event::SftStep { .. })
            | Some(Event::DeltaStreamed { .. })
            | Some(Event::Committed { .. }) => {}
            None => {
                return Err(session
                    .join()
                    .err()
                    .unwrap_or_else(|| anyhow::anyhow!("session ended without a report")))
            }
        }
    };
    println!(
        "\ndone: {} versions, mean rho {:.3}%, wall {:.1}s, hidden sync {:.0}%",
        report.final_version,
        report.mean_rho() * 100.0,
        report.wall_s,
        report.timeline.overlap_ratio(
            "trainer",
            &[sparrowrl::metrics::SpanKind::Train, sparrowrl::metrics::SpanKind::Extract],
        ) * 100.0,
    );
    // The cross-backend equivalence witness: identical runs (same seed,
    // --deterministic) print the same digest on every transport.
    if let Some(last) = report.steps.last() {
        println!("final policy checksum: {}", last.checksum_hex());
    }
    if report.failovers > 0 {
        println!(
            "failovers: {} actor(s) lost, {} prompt(s) requeued to survivors",
            report.failovers, report.requeued_prompts,
        );
    }
    if report.joins + report.drains + report.preempts > 0 {
        println!(
            "membership: {} join(s), {} graceful drain(s), {} preemption warning(s)",
            report.joins, report.drains, report.preempts,
        );
    }
    if report.swaps > 0 {
        println!("hot-swaps: {} actor(s) retargeted onto published fine-tunes", report.swaps);
    }
    if args.flag("gantt") {
        print!("{}", report.timeline.ascii_gantt(100));
    }
    Ok(())
}

/// `sparrowrl serve`: run the `sparrowrld` control-plane daemon in the
/// foreground — many concurrent sessions over one shared synthetic
/// actor pool, driven over HTTP/JSON (see `daemon` module docs and
/// docs/ARCHITECTURE.md §2f). Ctrl-C to stop; in-flight runs are
/// aborted cooperatively on shutdown.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use sparrowrl::daemon::{AlertRules, Daemon, DaemonConfig};
    let defaults = DaemonConfig::default();
    let rules = AlertRules {
        overlap_floor: args.get("alert-overlap-floor").map(|s| s.parse()).transpose()?,
        tokens_per_dollar_floor: args.get("alert-tpd-floor").map(|s| s.parse()).transpose()?,
        payload_ceiling_bytes: args
            .get("alert-payload-ceiling")
            .map(|s| s.parse())
            .transpose()?,
    };
    let registry = {
        let dir = args.str_or("registry", "");
        (!dir.is_empty()).then(|| std::path::PathBuf::from(dir))
    };
    let cfg = DaemonConfig {
        addr: args.str_or("addr", &defaults.addr),
        max_sessions: args.parse_or("max-sessions", defaults.max_sessions),
        actor_pool: args.parse_or("actor-pool", defaults.actor_pool),
        rules,
        registry,
        ..defaults
    };
    let max_sessions = cfg.max_sessions;
    let actor_pool = cfg.actor_pool;
    let handle = Daemon::spawn(cfg)?;
    println!(
        "sparrowrld listening on http://{} ({} session slots, {} shared actor slots)",
        handle.addr(),
        max_sessions,
        actor_pool,
    );
    println!("routes:");
    for route in [
        "POST /runs               submit a run spec (JSON)",
        "GET  /runs               list runs",
        "GET  /runs/{id}          run snapshot + live analytics",
        "POST /runs/{id}/abort    cooperative abort",
        "GET  /runs/{id}/events   SSE event stream (replay + tail)",
        "POST /runs/{id}/swap     script a hot-swap onto a queued run",
        "GET  /models             model registry listing",
        "POST /models             publish a durable run into the registry",
        "GET  /alerts             daemon-wide threshold alerts",
        "GET  /healthz            liveness probe",
    ] {
        println!("  {route}");
    }
    handle.wait();
    Ok(())
}

/// Offline recovery tooling over a durable store: verify the journal and
/// object chain, optionally fold the delta chain into one compacted
/// object (`--compact`, witness-verified before publication), and print
/// the reconstructed policy's SHA-256 checksum at `--version` (default:
/// the last journaled version). The checksum matches the live run's
/// `final policy checksum` line and the journaled witness — the
/// end-to-end durability proof.
fn cmd_reconstruct(args: &Args) -> anyhow::Result<()> {
    use sparrowrl::delta::{expect_run_dir, policy_witness, DurableStore, JournalRecord};
    // Registry mode: rebuild a *published* fine-tune (base snapshot +
    // one folded delta) instead of replaying a run dir's chain.
    let reg_dir = args.str_or("registry", "");
    if !reg_dir.is_empty() {
        return reconstruct_from_registry(args, &reg_dir);
    }
    let dir = args.str_or("persist-dir", "");
    if dir.is_empty() {
        anyhow::bail!("reconstruct needs --persist-dir DIR (or --registry DIR --model NAME)");
    }
    // A registry dir also has an objects/ pool; refuse it with the typed
    // error instead of a confusing journal failure downstream.
    expect_run_dir(std::path::Path::new(&dir))
        .map_err(|e| anyhow::anyhow!("reconstruct at {dir}: {e}"))?;
    let mut store =
        DurableStore::open(&dir).map_err(|e| anyhow::anyhow!("durable store at {dir}: {e}"))?;
    let model = args.str_or("model", "sparrow-xs");
    let spec = config::model(&model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let layout = &spec.layout;
    match store.records().first() {
        Some(JournalRecord::Genesis { model_fp, .. }) => anyhow::ensure!(
            *model_fp == layout.fingerprint(),
            "--model {model} does not match the persisted run (layout fingerprint mismatch)"
        ),
        _ => anyhow::bail!("{dir} holds no durable run"),
    }
    let last = store.last_version().expect("genesis checked above");
    let version = match args.get("version") {
        Some(v) => v.parse::<u64>()?,
        None => last,
    };
    if args.flag("compact") {
        let stats = store
            .compact(layout, None)
            .map_err(|e| anyhow::anyhow!("compacting chain: {e}"))?;
        println!(
            "compacted D_1..D_{}: {} -> {} ({:.1}% of the chain)",
            stats.upto,
            sparrowrl::util::fmt_bytes(stats.chain_bytes),
            sparrowrl::util::fmt_bytes(stats.compacted_bytes),
            100.0 * stats.compacted_bytes as f64 / stats.chain_bytes.max(1) as f64,
        );
    }
    let policy = store
        .reconstruct(layout, version)
        .map_err(|e| anyhow::anyhow!("reconstructing v{version}: {e}"))?;
    println!("v{version} policy checksum: {}", sparrowrl::util::hex(&policy_witness(&policy)));
    Ok(())
}

/// `reconstruct --registry DIR --model NAME`: rebuild a published
/// fine-tune from the registry (shared base + its folded delta) and
/// print the witness-verified checksum. `--layout` names the bench
/// layout preset (the registry stores only its fingerprint).
fn reconstruct_from_registry(args: &Args, reg_dir: &str) -> anyhow::Result<()> {
    use sparrowrl::delta::{policy_witness, ModelRegistry};
    let name = args.str_or("model", "");
    if name.is_empty() {
        anyhow::bail!("reconstruct --registry needs --model NAME (a published model)");
    }
    let layout_name = args.str_or("layout", "sparrow-xs");
    let spec = config::model(&layout_name)
        .ok_or_else(|| anyhow::anyhow!("unknown layout preset {layout_name}"))?;
    let reg = ModelRegistry::open(reg_dir)
        .map_err(|e| anyhow::anyhow!("model registry at {reg_dir}: {e}"))?;
    let manifest = reg
        .model(&name)
        .map_err(|e| anyhow::anyhow!("model registry at {reg_dir}: {e}"))?;
    let version = match args.get("version") {
        Some(v) => v.parse::<u64>()?,
        None => manifest
            .versions
            .last()
            .map(|v| v.version)
            .ok_or_else(|| anyhow::anyhow!("model {name} has no published versions"))?,
    };
    let policy = reg
        .reconstruct(&spec.layout, &name, version)
        .map_err(|e| anyhow::anyhow!("reconstructing {name}@v{version}: {e}"))?;
    println!(
        "{name}@v{version} policy checksum: {}",
        sparrowrl::util::hex(&policy_witness(&policy))
    );
    Ok(())
}

/// `sparrowrl registry`: the multi-run model registry. `list` shows the
/// namespace (models, versions, shared bases), `publish` folds a durable
/// run's chain into one compacted delta off the shared base, and `gc`
/// sweeps unreferenced objects (bases and versions still referenced by a
/// manifest or pinned by an in-flight swap survive).
fn cmd_registry(args: &Args) -> anyhow::Result<()> {
    use sparrowrl::delta::{expect_run_dir, DurableStore, ModelRegistry};
    let dir = args.str_or("registry", "");
    if dir.is_empty() {
        anyhow::bail!("registry commands need --registry DIR");
    }
    let open = || {
        ModelRegistry::open(&dir).map_err(|e| anyhow::anyhow!("model registry at {dir}: {e}"))
    };
    match args.positional.get(1).map(|s| s.as_str()).unwrap_or("") {
        "list" => {
            let reg = open()?;
            if reg.models().is_empty() {
                println!("registry {dir}: no published models");
                return Ok(());
            }
            for (sha, base) in reg.bases() {
                println!(
                    "base {} ({}, layout fp {:016x})",
                    &sha[..12.min(sha.len())],
                    sparrowrl::util::fmt_bytes(base.bytes),
                    base.model_fp,
                );
            }
            for manifest in reg.models().values() {
                println!(
                    "model {} (base {}):",
                    manifest.name,
                    &manifest.base[..12.min(manifest.base.len())],
                );
                for vref in &manifest.versions {
                    println!(
                        "  v{} object {} ({}) witness {}",
                        vref.version,
                        &vref.object[..12.min(vref.object.len())],
                        sparrowrl::util::fmt_bytes(vref.payload_bytes),
                        &sparrowrl::util::hex(&vref.witness)[..16],
                    );
                }
            }
            Ok(())
        }
        "publish" => {
            let run = args.str_or("persist-dir", "");
            if run.is_empty() {
                anyhow::bail!("registry publish needs --persist-dir RUN (the durable run to fold)");
            }
            expect_run_dir(std::path::Path::new(&run))
                .map_err(|e| anyhow::anyhow!("registry publish from {run}: {e}"))?;
            let store = DurableStore::open(&run)
                .map_err(|e| anyhow::anyhow!("durable store at {run}: {e}"))?;
            let name = args.str_or("name", "");
            if name.is_empty() {
                anyhow::bail!("registry publish needs --name NAME");
            }
            let layout_name = args.str_or("model", "sparrow-xs");
            let spec = config::model(&layout_name)
                .ok_or_else(|| anyhow::anyhow!("unknown model {layout_name}"))?;
            let version = args.get("version").map(|v| v.parse::<u64>()).transpose()?;
            let mut reg = open()?;
            let report = reg
                .publish(&store, &spec.layout, &name, version)
                .map_err(|e| anyhow::anyhow!("publishing {run} as {name}: {e}"))?;
            println!(
                "published {}@v{}: folded delta {} ({}, {}), base {} ({}, {})",
                report.model,
                report.version,
                &report.object[..12.min(report.object.len())],
                sparrowrl::util::fmt_bytes(report.payload_bytes),
                if report.object_was_new { "new" } else { "deduplicated" },
                &report.base[..12.min(report.base.len())],
                sparrowrl::util::fmt_bytes(report.base_bytes),
                if report.base_was_new { "new" } else { "shared" },
            );
            Ok(())
        }
        "gc" => {
            let mut reg = open()?;
            let stats = reg
                .gc()
                .map_err(|e| anyhow::anyhow!("registry gc at {dir}: {e}"))?;
            println!(
                "gc: scanned {} object(s), collected {} ({}), {} pinned object(s) retained",
                stats.scanned,
                stats.collected,
                sparrowrl::util::fmt_bytes(stats.collected_bytes),
                stats.retained_pinned,
            );
            Ok(())
        }
        other => anyhow::bail!("unknown registry subcommand {other:?} (list|publish|gc)"),
    }
}

/// `sparrowrl bench`: the declarative scenario-matrix harness.
///
/// * `bench run` expands a suite (built-in `smoke`/`full` or a
///   `--file` JSON matrix), runs every cell through the Session API on
///   SyntheticCompute, and writes one `ResultSet` file.
/// * `bench compare OLD NEW` diffs two result files per scenario key
///   and exits nonzero on regression beyond `--threshold` (percent), on
///   any drift of an exact-gated metric, or on a changed determinism
///   witness — the CI regression gate.
/// * `bench list` prints the expanded cell keys without running them.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use sparrowrl::bench::{compare, ResultSet, Suite};
    fn load_suite(args: &Args) -> anyhow::Result<Suite> {
        let file = args.str_or("file", "");
        if !file.is_empty() {
            let text = std::fs::read_to_string(&file)
                .map_err(|e| anyhow::anyhow!("reading {file}: {e}"))?;
            return Suite::from_json(&text).map_err(|e| anyhow::anyhow!("{file}: {e}"));
        }
        let name = args.str_or("suite", "smoke");
        sparrowrl::bench::builtin_suite(&name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown suite {name} (built-in: {}; or pass --file)",
                sparrowrl::bench::SUITE_NAMES.join(", ")
            )
        })
    }
    match args.positional.get(1).map(|s| s.as_str()).unwrap_or("") {
        "run" => {
            let suite = load_suite(args)?;
            let cells = suite.expand()?;
            println!("suite {}: {} scenario cell(s)", suite.name, cells.len());
            let results = sparrowrl::bench::run_suite(&suite.name, &cells)?;
            let out = args.str_or("out", &format!("BENCH_{}.json", suite.name));
            results.write(std::path::Path::new(&out))?;
            println!("bench results written to {out}");
            Ok(())
        }
        "compare" => {
            let (Some(old_path), Some(new_path)) =
                (args.positional.get(2), args.positional.get(3))
            else {
                anyhow::bail!("usage: sparrowrl bench compare OLD NEW [--threshold PCT]");
            };
            let threshold =
                args.parse_or("threshold", sparrowrl::bench::DEFAULT_THRESHOLD_PCT);
            let old = ResultSet::load(std::path::Path::new(old_path))?;
            let new = ResultSet::load(std::path::Path::new(new_path))?;
            let report = compare(&old, &new, threshold);
            print!("{}", report.render());
            if report.passed() {
                Ok(())
            } else {
                anyhow::bail!(
                    "bench compare: {} gating failure(s) (threshold ±{threshold}%)",
                    report.failures(),
                )
            }
        }
        "list" => {
            let suite = load_suite(args)?;
            for sc in suite.expand()? {
                println!("{}", sc.key());
            }
            Ok(())
        }
        // Promote a green CI artifact (`BENCH_smoke.json`) to be the
        // committed baseline, replacing the bootstrap placeholder. The
        // artifact is validated (schema, non-placeholder, non-empty)
        // before anything is overwritten.
        "promote" => {
            let Some(artifact) = args.positional.get(2) else {
                anyhow::bail!("usage: sparrowrl bench promote ARTIFACT [--baseline PATH]");
            };
            let baseline = args.str_or("baseline", "../bench/baseline_smoke.json");
            let set = ResultSet::load(std::path::Path::new(artifact))?;
            if set.placeholder {
                anyhow::bail!(
                    "{artifact} is itself a placeholder; promote a real CI artifact instead"
                );
            }
            if set.records.is_empty() {
                anyhow::bail!("{artifact} holds no scenario records; refusing to promote");
            }
            set.write(std::path::Path::new(&baseline))?;
            println!(
                "promoted {artifact} -> {baseline} (suite {}, {} record(s))",
                set.suite,
                set.records.len(),
            );
            Ok(())
        }
        other => {
            anyhow::bail!("unknown bench subcommand {other:?} (run|compare|list|promote)")
        }
    }
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let model = config::model(&args.str_or("model", "qwen3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let system = match args.str_or("system", "sparrow").as_str() {
        "sparrow" => System::Sparrow,
        "full" => System::PrimeRlFull,
        "ms" => System::PrimeRlMultiStream,
        "ideal" => System::IdealSingleDc,
        other => anyhow::bail!("unknown system {other}"),
    };
    let bench = Benchmark::parse(&args.str_or("bench", "gsm8k"))
        .ok_or_else(|| anyhow::anyhow!("bad --bench"))?;
    let n = args.parse_or("actors", 8usize);
    let region = config::regions::by_name(&args.str_or("region", "canada"))
        .ok_or_else(|| anyhow::anyhow!("unknown region"))?;
    let fleet = vec![RegionSpec::new(region, vec![config::GpuClass::A100; n])];
    let mut cfg = SimConfig::paper_testbed(model, bench, system, fleet);
    cfg.steps = args.parse_or("steps", 7u64);
    cfg.streams = args.parse_or("streams", 4usize);
    let r = sim_run(&cfg);
    println!(
        "{}: {:.0} tokens/s, avg step {:.1}s, avg transfer {:.2}s, payload {}",
        r.system.name(),
        r.throughput(),
        r.avg_step_time(),
        r.avg_transfer_time(),
        sparrowrl::util::fmt_bytes(r.payload_bytes()),
    );
    if args.flag("gantt") {
        print!("{}", r.timeline.ascii_gantt(100));
    }
    Ok(())
}
