//! SparrowRL launcher CLI.
//!
//! ```text
//! sparrowrl exp <id> [--flags]   reproduce a paper table/figure (or 'all')
//! sparrowrl train [--flags]      run the real RL loop on PJRT artifacts
//! sparrowrl sim [--flags]        one simulated geo-distributed run
//! sparrowrl list                 list experiments and models
//! ```

use sparrowrl::config;
use sparrowrl::data::Benchmark;
use sparrowrl::exp;
use sparrowrl::session::{Backend, Event, RunSpec, Session};
use sparrowrl::sim::driver::{run as sim_run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::trainer::Algorithm;
use sparrowrl::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage:\n  sparrowrl exp <{}|all> [--flags]\n  sparrowrl train [--model sparrow-xs] \
         [--steps N] [--sft-steps N] [--algorithm grpo|rloo|opo] [--lr-rl X] [--actors N] [--seed S] [--pipelined] \
         [--transport inproc|sim|tcp] [--tcp-streams N] [--tcp-bps BITS] [--deterministic] [--wan wan-1..wan-4] [--gantt]\n  \
         sparrowrl sim [--model qwen3-8b] [--system sparrow|full|ms|ideal] [--bench gsm8k|math|deepscaler] [--steps N]\n  \
         sparrowrl list",
        exp::ALL.join("|")
    );
    std::process::exit(2);
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "exp" => {
            let Some(id) = args.positional.get(1).map(|s| s.to_string()) else { usage() };
            exp::run(&id, &args)
        }
        "train" => cmd_train(&args),
        "sim" => cmd_sim(&args),
        "list" => {
            println!("experiments: {}", exp::ALL.join(", "));
            println!("runnable models: {}", config::runnable_models().join(", "));
            println!("analytic models: {}", config::paper_models().join(", "));
            println!("transports: {}", Backend::NAMES.join(", "));
            println!("wan presets: {}", config::WAN_PRESET_NAMES.join(", "));
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `train` flags into a [`RunSpec`]. All cross-field legality
/// rules (wan↔actors, wan↔tcp, transport→pipelined coercions, ...) live
/// in `RunSpec::build`, not here — this is string parsing only.
fn train_spec(args: &Args) -> anyhow::Result<RunSpec> {
    let model = args.str_or("model", "sparrow-xs");
    let mut spec = RunSpec::model(&model)
        .steps(args.parse_or("steps", 10u64))
        .sft_steps(args.parse_or("sft-steps", 50u64))
        .lr_sft(args.parse_or("lr-sft", 5e-3f32))
        .lr_rl(args.parse_or("lr-rl", 1e-6f32))
        .seed(args.parse_or("seed", 0u64))
        .max_new_tokens(args.parse_or("max-new", 8usize))
        .algorithm(
            Algorithm::parse(&args.str_or("algorithm", "grpo"))
                .ok_or_else(|| anyhow::anyhow!("bad --algorithm"))?,
        )
        .bench(
            Benchmark::parse(&args.str_or("bench", "gsm8k"))
                .ok_or_else(|| anyhow::anyhow!("bad --bench"))?,
        );
    if args.get("actors").is_some() {
        spec = spec.actors(args.parse_or("actors", 2usize));
    }
    if args.flag("pipelined") {
        spec = spec.pipelined();
    }
    if args.flag("deterministic") {
        spec = spec.deterministic();
    }
    let wan = args.str_or("wan", "");
    if !wan.is_empty() {
        spec = spec.wan(&wan);
    }
    let tname = args.str_or("transport", "inproc");
    let mut backend = Backend::parse(&tname)
        .ok_or_else(|| anyhow::anyhow!("unknown --transport {tname} (inproc|sim|tcp)"))?;
    if let Backend::Tcp(tc) = &mut backend {
        tc.streams = args.parse_or("tcp-streams", 2usize);
        tc.bits_per_s = args.get("tcp-bps").and_then(|s| s.parse::<f64>().ok());
    }
    Ok(spec.transport(backend))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let plan = train_spec(args)?.build()?;
    for note in plan.notes() {
        println!("note: {note}");
    }
    let cfg = plan.config();
    println!(
        "training {} with {} on {} ({} actors, {} SFT + {} RL steps, {} executor, {} transport)",
        cfg.model,
        cfg.algorithm.name(),
        cfg.bench.name(),
        cfg.n_actors,
        cfg.sft_steps,
        cfg.steps,
        plan.mode().name(),
        cfg.transport.name(),
    );
    // The CLI is just one subscriber of the session's typed event
    // stream: per-step lines, failover notices, and the final report all
    // come out of the same events a dashboard would consume.
    let mut session = Session::start(&plan)?;
    let report = loop {
        match session.recv() {
            Some(Event::StepCompleted(log)) => println!("{}", log.progress_line()),
            Some(Event::Failover { actor, requeued }) => {
                eprintln!("actor {actor} lost; {requeued} prompt(s) requeued to survivors")
            }
            Some(Event::Finished(report)) => break report,
            // Warmup progress and per-version stream/commit events are
            // summarized by the step line; skip them here.
            Some(Event::SftStep { .. })
            | Some(Event::DeltaStreamed { .. })
            | Some(Event::Committed { .. }) => {}
            None => {
                return Err(session
                    .join()
                    .err()
                    .unwrap_or_else(|| anyhow::anyhow!("session ended without a report")))
            }
        }
    };
    println!(
        "\ndone: {} versions, mean rho {:.3}%, wall {:.1}s, hidden sync {:.0}%",
        report.final_version,
        report.mean_rho() * 100.0,
        report.wall_s,
        report.timeline.overlap_ratio(
            "trainer",
            &[sparrowrl::metrics::SpanKind::Train, sparrowrl::metrics::SpanKind::Extract],
        ) * 100.0,
    );
    // The cross-backend equivalence witness: identical runs (same seed,
    // --deterministic) print the same digest on every transport.
    if let Some(last) = report.steps.last() {
        println!("final policy checksum: {}", last.checksum_hex());
    }
    if report.failovers > 0 {
        println!(
            "failovers: {} actor(s) lost, {} prompt(s) requeued to survivors",
            report.failovers, report.requeued_prompts,
        );
    }
    if args.flag("gantt") {
        print!("{}", report.timeline.ascii_gantt(100));
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let model = config::model(&args.str_or("model", "qwen3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let system = match args.str_or("system", "sparrow").as_str() {
        "sparrow" => System::Sparrow,
        "full" => System::PrimeRlFull,
        "ms" => System::PrimeRlMultiStream,
        "ideal" => System::IdealSingleDc,
        other => anyhow::bail!("unknown system {other}"),
    };
    let bench = Benchmark::parse(&args.str_or("bench", "gsm8k"))
        .ok_or_else(|| anyhow::anyhow!("bad --bench"))?;
    let n = args.parse_or("actors", 8usize);
    let region = config::regions::by_name(&args.str_or("region", "canada"))
        .ok_or_else(|| anyhow::anyhow!("unknown region"))?;
    let fleet = vec![RegionSpec::new(region, vec![config::GpuClass::A100; n])];
    let mut cfg = SimConfig::paper_testbed(model, bench, system, fleet);
    cfg.steps = args.parse_or("steps", 7u64);
    cfg.streams = args.parse_or("streams", 4usize);
    let r = sim_run(&cfg);
    println!(
        "{}: {:.0} tokens/s, avg step {:.1}s, avg transfer {:.2}s, payload {}",
        r.system.name(),
        r.throughput(),
        r.avg_step_time(),
        r.avg_transfer_time(),
        sparrowrl::util::fmt_bytes(r.payload_bytes()),
    );
    if args.flag("gantt") {
        print!("{}", r.timeline.ascii_gantt(100));
    }
    Ok(())
}
