//! Rollout Actor: staged delta activation over actor-resident parameters
//! (paper §5.2 "Staged activation") plus the rollout generation loop
//! (`rollout.rs`, PJRT-backed).
//!
//! Invariants enforced here:
//! * rollouts are never served from a partially applied policy — deltas
//!   stage in a side buffer and apply only at a safe point on Commit;
//! * a delta applies only if its `base_version` equals the active version
//!   (out-of-order / replayed deltas are rejected);
//! * the active-version tag advances only after the scatter completes;
//! * a `Commit(v)` that overtakes `D_v` segments still in flight (striped
//!   WAN streams and relay forwarding reorder freely) parks and lands once
//!   the last segment completes staging — reordering never poisons an
//!   otherwise healthy stream.
//!
//! Staging runs through the streaming decoder (`delta/stream.rs`): each
//! arriving segment is parsed incrementally and its payload freed, so the
//! actor never buffers the full checkpoint byte stream the way the old
//! `Reassembler`-then-`decode_delta` path did, and Commit applies the
//! already-parsed delta without a second decode pass. The hash check still
//! happens before anything is staged: a delta enters `staged` only after
//! its SHA-256 trailer verified.

pub mod rollout;

use crate::delta::stream::{DeltaStreamDecoder, StagedDelta};
use crate::delta::{
    apply_delta, ApplyMode, DeltaCheckpoint, ModelLayout, ParamSet, SparseDelta, TensorDelta,
};
use crate::transport::Segment;
use crate::util::Bf16;
use std::collections::BTreeMap;

/// Outcome of a commit attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitResult {
    /// Applied; active version advanced.
    Applied,
    /// A generation batch is running: the commit was parked and will apply
    /// at the next safe point ([`PolicyState::on_safe_point`]).
    Deferred,
    /// No fully staged delta for that version yet.
    NotStaged,
    /// Staged delta's base does not match the active version.
    BaseMismatch { active: u64, base: u64 },
    /// Decode/integrity failure (corrupt staging).
    Corrupt,
}

/// The actor's policy state machine.
pub struct PolicyState {
    layout: ModelLayout,
    params: ParamSet,
    active_version: u64,
    /// Checkpoint hash of the active version (all-zero genesis before the
    /// first commit) — echoed in every rollout result so the hub's job
    /// ledger can run the §5.4 acceptance predicate across processes.
    active_hash: [u8; 32],
    /// In-flight streaming decoders, by version (segments parsed and
    /// freed on arrival; working set is one partial section each).
    staging: BTreeMap<u64, DeltaStreamDecoder>,
    /// Fully received, hash-verified deltas awaiting Commit.
    staged: BTreeMap<u64, StagedDelta>,
    /// True while a generation batch is running (no safe point).
    generating: bool,
    /// Commit requested mid-generation, parked for the next safe point.
    pending_commit: Option<u64>,
    /// Behaviour-policy retention for failover: the version the last
    /// commit replaced, reconstructible by applying `inverse` (the sparse
    /// old-values delta captured during the scatter) to the live params.
    /// Storage is O(rho) of the model — the same lossless-sparse-delta
    /// trick the transfer path uses, pointed backwards.
    retained: Option<RetainedVersion>,
    applied: u64,
}

/// The pre-commit identity of the version the active policy replaced.
struct RetainedVersion {
    version: u64,
    hash: [u8; 32],
    inverse: SparseDelta,
}

/// Sparse inverse of `delta` against the *current* (pre-apply) params:
/// same indices, the old values they hold now, always `Assign` mode.
/// Capturing old values (rather than negating an `Add` delta) is what
/// makes the reconstruction bit-exact for *both* apply modes — bf16
/// addition rounds, so `round(round(a + v) - v)` need not equal `a`, but
/// re-assigning the captured `a` always does.
pub(crate) fn invert_delta(params: &ParamSet, delta: &SparseDelta) -> SparseDelta {
    let tensors = delta
        .tensors
        .iter()
        .map(|t| {
            let buf = &params.tensors[t.tensor as usize];
            let vals: Vec<Bf16> = t.idx.iter().map(|&i| buf[i as usize]).collect();
            TensorDelta { tensor: t.tensor, idx: t.idx.clone(), vals }
        })
        .collect();
    SparseDelta {
        version: delta.base_version,
        base_version: delta.version,
        model_fp: delta.model_fp,
        mode: ApplyMode::Assign,
        tensors,
    }
}

impl PolicyState {
    pub fn new(layout: ModelLayout, params: ParamSet, version: u64) -> PolicyState {
        PolicyState {
            layout,
            params,
            active_version: version,
            active_hash: [0u8; 32],
            staging: BTreeMap::new(),
            staged: BTreeMap::new(),
            generating: false,
            pending_commit: None,
            retained: None,
            applied: 0,
        }
    }

    /// Builder: set the active checkpoint hash. A worker spun up mid-run
    /// (hub resume) starts at the resumed version, not genesis, and the
    /// ledger's acceptance predicate compares this hash against the
    /// lease's — `[0; 32]` would reject every result.
    pub fn with_active_hash(mut self, hash: [u8; 32]) -> PolicyState {
        self.active_hash = hash;
        self
    }

    pub fn active_version(&self) -> u64 {
        self.active_version
    }

    /// Checkpoint hash of the active version ([0; 32] at genesis). This
    /// is the `h_r` an actor attaches to results; the ledger accepts a
    /// rollout only if it matches the lease's `h(v_job)`.
    pub fn active_hash(&self) -> [u8; 32] {
        self.active_hash
    }

    /// Resolve the policy bits + checkpoint hash to generate `version`'s
    /// rollouts on: the active policy, or — when staged activation has
    /// already rolled this actor to `version + 1` mid-step (a commit at
    /// an inter-batch safe point) — the replaced version rebuilt by
    /// applying the retained sparse inverse. The failover path depends on
    /// this: a job re-issued from a dead peer still targets the step's
    /// lease version, and regeneration must be bit-identical. `None` if
    /// `version` is neither active nor retained (too far behind).
    pub fn behaviour_policy(&self, version: u64) -> Option<(ParamSet, [u8; 32])> {
        if version == self.active_version {
            return Some((self.params.clone(), self.active_hash));
        }
        let r = self.retained.as_ref().filter(|r| r.version == version)?;
        let mut params = self.params.clone();
        apply_delta(&mut params, &r.inverse);
        Some((params, r.hash))
    }

    pub fn params(&self) -> &ParamSet {
        &self.params
    }

    /// Install a full-policy snapshot (the elastic-membership bootstrap
    /// fallback when the delta chain is unavailable): the wire bytes of
    /// [`ParamSet::to_snapshot_bytes`] become the active policy at
    /// `version`, with `hash` as its checkpoint hash for the ledger
    /// predicate. Only ever moves forward; staging and retention at or
    /// below the snapshot are discarded (there is no older state to roll
    /// back to on a freshly bootstrapped actor).
    pub fn install_snapshot(
        &mut self,
        version: u64,
        hash: [u8; 32],
        data: &[u8],
    ) -> Result<(), String> {
        if version <= self.active_version {
            return Err(format!(
                "snapshot version {version} not ahead of active {}",
                self.active_version
            ));
        }
        self.params = ParamSet::from_snapshot_bytes(&self.layout, data)?;
        self.active_version = version;
        self.active_hash = hash;
        self.staging.retain(|&v, _| v > version);
        self.staged.retain(|&v, _| v > version);
        if self.pending_commit.map_or(false, |p| p <= version) {
            self.pending_commit = None;
        }
        self.retained = None;
        self.applied += 1;
        Ok(())
    }

    pub fn highest_staged(&self) -> Option<u64> {
        self.staged.keys().next_back().copied()
    }

    pub fn is_staged(&self, version: u64) -> bool {
        self.staged.contains_key(&version)
    }

    pub fn set_generating(&mut self, generating: bool) {
        self.generating = generating;
    }

    /// Ingest one transfer segment; the streaming decoder parses it (and
    /// frees its payload) in the background of generation. Returns true
    /// when `seg`'s version became fully staged by this call.
    pub fn on_segment(&mut self, seg: Segment) -> Result<bool, String> {
        let v = seg.version;
        if v <= self.active_version || self.staged.contains_key(&v) {
            return Ok(false); // stale or already staged; drop quietly
        }
        let d = self.staging.entry(v).or_insert_with(|| DeltaStreamDecoder::new(v));
        match d.push(seg) {
            Ok(true) => {
                let dec = self.staging.remove(&v).unwrap();
                let staged = dec.into_staged().expect("complete decoder yields a delta");
                self.staged.insert(v, staged);
                Ok(true)
            }
            Ok(false) => Ok(false),
            Err(e) => {
                // A poisoned decoder can never complete: discard it so a
                // clean retransmit restages from scratch (the legacy
                // Reassembler path recovered the same way).
                if d.is_poisoned() {
                    self.staging.remove(&v);
                }
                Err(format!("streaming staging failed: {e}"))
            }
        }
    }

    /// Stage a checkpoint delivered whole (relay handoff / tests). The
    /// artifact is decoded once here; corrupt artifacts are dropped (a
    /// later Commit simply reports `NotStaged`).
    pub fn stage_checkpoint(&mut self, ckpt: DeltaCheckpoint) {
        if ckpt.version > self.active_version {
            if let Ok(delta) = ckpt.open() {
                self.staged
                    .insert(ckpt.version, StagedDelta { delta, hash: ckpt.hash });
            }
        }
    }

    /// Commit `version`: apply the staged delta at a safe point. Refuses
    /// mid-generation (caller retries at batch end) — callers treat a
    /// `false` from `safe_point` as "wait".
    pub fn commit(&mut self, version: u64) -> CommitResult {
        assert!(!self.generating, "commit must happen at a safe point");
        let Some(staged) = self.staged.get(&version) else {
            return CommitResult::NotStaged;
        };
        if staged.delta.base_version != self.active_version {
            return CommitResult::BaseMismatch {
                active: self.active_version,
                base: staged.delta.base_version,
            };
        }
        // Already parsed and hash-verified at staging time; only the
        // layout validation remains before the scatter.
        if staged.delta.validate(&self.layout).is_err() {
            return CommitResult::Corrupt;
        }
        let applied_hash = staged.hash;
        // Retain the replaced version as a sparse inverse before the
        // scatter overwrites it: a failover job may still target it.
        self.retained = Some(RetainedVersion {
            version: self.active_version,
            hash: self.active_hash,
            inverse: invert_delta(&self.params, &staged.delta),
        });
        apply_delta(&mut self.params, &staged.delta);
        // Advance the tag only after the scatter completed (§5.2).
        self.active_version = version;
        self.active_hash = applied_hash;
        self.applied += 1;
        self.staged.remove(&version);
        // Garbage-collect staging state that can never apply now — and any
        // deferred commit request this apply already satisfied.
        self.staging.retain(|&v, _| v > version);
        self.staged.retain(|&v, _| v > version);
        if self.pending_commit.map_or(false, |p| p <= version) {
            self.pending_commit = None;
        }
        CommitResult::Applied
    }

    /// Asynchronous commit entry point (the hub's mailbox delivery): apply
    /// immediately if the actor is at a safe point, otherwise park the
    /// request and return [`CommitResult::Deferred`] — it lands via
    /// [`on_safe_point`](Self::on_safe_point) between generation batches.
    /// A newer deferred request supersedes an older one (the later delta
    /// chains through `commit_chain`-style catch-up on apply).
    ///
    /// A request for a *future* version whose delta is not fully staged
    /// yet also parks: under multi-path delivery (striped WAN streams,
    /// relay forwarding) a `Commit(v)` can overtake `D_v` segments still
    /// in flight, and failing it would poison an otherwise healthy stream.
    /// The parked commit lands once the last segment completes staging
    /// (the segment path calls [`on_safe_point`](Self::on_safe_point)).
    pub fn request_commit(&mut self, version: u64) -> CommitResult {
        if self.generating || self.chain_in_flight(version) {
            let v = self.pending_commit.map_or(version, |p| p.max(version));
            self.pending_commit = Some(v);
            return CommitResult::Deferred;
        }
        self.commit(version)
    }

    /// True while any delta on the commit chain `active+1 ..= version` has
    /// not fully staged yet. Multi-path delivery can reorder *whole
    /// deltas*, not just segments — a small `D_v` on fast stripes can
    /// complete while `D_{v-1}` is still in flight — and applying early
    /// would fail with `BaseMismatch` instead of waiting.
    fn chain_in_flight(&self, version: u64) -> bool {
        if version <= self.active_version {
            return false;
        }
        // A staged delta that applies directly onto the active version —
        // a compacted chain folded into one artifact (delta::merge_chain)
        // — is complete in itself; the versions it skips over will never
        // arrive and must not keep the commit parked.
        if self
            .staged
            .get(&version)
            .map_or(false, |s| s.delta.base_version == self.active_version)
        {
            return false;
        }
        (self.active_version + 1..=version).any(|w| !self.staged.contains_key(&w))
    }

    /// Safe-point hook: called by the generation loop between batches
    /// (`generating == false`) and after staging progress. Applies a
    /// commit parked by [`request_commit`](Self::request_commit), chaining
    /// through any intermediate staged versions, and reports what
    /// happened. `None` when nothing was pending, no safe point was
    /// reached, or the pending version's segments are still in flight
    /// (reordered multi-stream delivery: retry on the next call).
    pub fn on_safe_point(&mut self) -> Option<(u64, CommitResult)> {
        if self.generating {
            return None;
        }
        let v = self.pending_commit?;
        if self.chain_in_flight(v) {
            return None; // deltas still in flight; keep the commit parked
        }
        self.pending_commit = None;
        // Chain intermediate versions so a deferred v+k lands from v.
        while self.active_version < v.saturating_sub(1) && self.commit(self.active_version + 1) == CommitResult::Applied {}
        Some((v, self.commit(v)))
    }

    pub fn has_pending_commit(&self) -> bool {
        self.pending_commit.is_some()
    }

    /// Catch-up: apply every staged version that chains from the active
    /// one (laggards "catch up asynchronously without blocking others").
    pub fn commit_chain(&mut self) -> u64 {
        let mut applied = 0;
        while let Some((&v, _)) = self.staged.iter().next() {
            if self.commit(v) == CommitResult::Applied {
                applied += 1;
            } else {
                break;
            }
        }
        applied
    }

    pub fn applied_count(&self) -> u64 {
        self.applied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, ApplyMode};
    use crate::transport::split_into_segments;
    use crate::util::{Bf16, Rng};

    fn setup() -> (ModelLayout, ParamSet) {
        let l = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(1);
        let p = ParamSet::random(&l, 0.02, &mut rng);
        (l, p)
    }

    fn perturbed(p: &ParamSet, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut q = p.clone();
        for t in &mut q.tensors {
            for _ in 0..4 {
                let i = rng.range(0, t.len());
                t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0008);
            }
        }
        q
    }

    fn ckpt(l: &ModelLayout, from: &ParamSet, to: &ParamSet, base: u64, v: u64) -> DeltaCheckpoint {
        DeltaCheckpoint::seal(&extract_delta(l, from, to, base, v, ApplyMode::Assign))
    }

    #[test]
    fn segment_staging_then_commit_reproduces_snapshot() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 2);
        let c = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0, 0);
        let segs = split_into_segments(1, &c.bytes, 64);
        let mut became_staged = false;
        for s in segs {
            became_staged |= st.on_segment(s).unwrap();
        }
        assert!(became_staged);
        assert!(st.is_staged(1));
        assert_eq!(st.active_version(), 0, "staging must not activate");
        assert_eq!(st.commit(1), CommitResult::Applied);
        assert_eq!(st.active_version(), 1);
        assert_eq!(st.params(), &p1, "bit-exact after commit");
    }

    #[test]
    fn active_hash_tracks_committed_checkpoints() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 51);
        let p2 = perturbed(&p1, 52);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let c2 = ckpt(&l, &p1, &p2, 1, 2);
        let (h1, h2) = (c1.hash, c2.hash);
        let mut st = PolicyState::new(l, p0, 0);
        assert_eq!(st.active_hash(), [0u8; 32], "genesis hash");
        st.stage_checkpoint(c1);
        assert_eq!(st.active_hash(), [0u8; 32], "staging must not change it");
        assert_eq!(st.commit(1), CommitResult::Applied);
        assert_eq!(st.active_hash(), h1);
        st.stage_checkpoint(c2);
        assert_eq!(st.commit(2), CommitResult::Applied);
        assert_eq!(st.active_hash(), h2);
    }

    #[test]
    fn compacted_delta_commits_without_intermediate_versions() {
        // A joiner bootstrapped from a compacted chain receives ONE
        // delta spanning 0 -> k. The versions it skips will never
        // arrive, so request_commit must not park waiting for them.
        let (l, p0) = setup();
        let p3 = perturbed(&perturbed(&perturbed(&p0, 71), 72), 73);
        let folded = ckpt(&l, &p0, &p3, 0, 3);
        let h3 = folded.hash;
        let mut st = PolicyState::new(l, p0, 0);
        st.stage_checkpoint(folded);
        assert_eq!(st.request_commit(3), CommitResult::Applied, "must not defer");
        assert_eq!(st.active_version(), 3);
        assert_eq!(st.active_hash(), h3);
        assert_eq!(st.params(), &p3, "bit-exact through the folded delta");
    }

    #[test]
    fn compacted_delta_lands_from_parked_commit_at_safe_point() {
        // Same folded-chain shape, but the Commit overtakes the delta
        // segments: it parks, then lands once staging completes.
        let (l, p0) = setup();
        let p2 = perturbed(&perturbed(&p0, 81), 82);
        let folded = ckpt(&l, &p0, &p2, 0, 2);
        let mut st = PolicyState::new(l, p0, 0);
        assert_eq!(st.request_commit(2), CommitResult::Deferred, "nothing staged yet");
        st.stage_checkpoint(folded);
        assert_eq!(st.on_safe_point(), Some((2, CommitResult::Applied)));
        assert_eq!(st.active_version(), 2);
        assert_eq!(st.params(), &p2);
    }

    #[test]
    fn with_active_hash_seeds_resumed_workers() {
        let (l, p0) = setup();
        let h = [7u8; 32];
        let st = PolicyState::new(l, p0, 5).with_active_hash(h);
        assert_eq!(st.active_version(), 5);
        assert_eq!(st.active_hash(), h);
    }

    #[test]
    fn behaviour_policy_serves_active_and_retained_versions() {
        // Failover contract: after committing v+1, the actor can still
        // rebuild v bit-exactly (sparse inverse), so a job re-issued from
        // a dead peer regenerates on the lease's version.
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 61);
        let p2 = perturbed(&p1, 62);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let c2 = ckpt(&l, &p1, &p2, 1, 2);
        let (h1, h2) = (c1.hash, c2.hash);
        let mut st = PolicyState::new(l, p0.clone(), 0);
        assert_eq!(st.behaviour_policy(0), Some((p0.clone(), [0u8; 32])));
        assert!(st.behaviour_policy(1).is_none(), "future versions unknown");
        st.stage_checkpoint(c1);
        assert_eq!(st.commit(1), CommitResult::Applied);
        // Active v1 and retained v0 both resolvable, bit-exact.
        assert_eq!(st.behaviour_policy(1), Some((p1.clone(), h1)));
        assert_eq!(st.behaviour_policy(0), Some((p0, [0u8; 32])));
        st.stage_checkpoint(c2);
        assert_eq!(st.commit(2), CommitResult::Applied);
        assert_eq!(st.behaviour_policy(2), Some((p2, h2)));
        assert_eq!(st.behaviour_policy(1), Some((p1, h1)));
        assert!(st.behaviour_policy(0).is_none(), "only one version retained");
    }

    #[test]
    fn commit_without_staging_is_refused() {
        let (l, p0) = setup();
        let mut st = PolicyState::new(l, p0, 0);
        assert_eq!(st.commit(1), CommitResult::NotStaged);
    }

    #[test]
    fn base_version_mismatch_rejected() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 3);
        let p2 = perturbed(&p1, 4);
        // Delta 2 has base 1, but actor is still on 0.
        let c2 = ckpt(&l, &p1, &p2, 1, 2);
        let mut st = PolicyState::new(l, p0, 0);
        st.stage_checkpoint(c2);
        assert_eq!(
            st.commit(2),
            CommitResult::BaseMismatch { active: 0, base: 1 }
        );
        assert_eq!(st.active_version(), 0);
    }

    #[test]
    fn laggard_catches_up_through_chained_commits() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 5);
        let p2 = perturbed(&p1, 6);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let c2 = ckpt(&l, &p1, &p2, 1, 2);
        let mut st = PolicyState::new(l, p0.clone(), 0);
        st.stage_checkpoint(c2);
        st.stage_checkpoint(c1);
        assert_eq!(st.commit_chain(), 2);
        assert_eq!(st.active_version(), 2);
        assert_eq!(st.params(), &p2);
    }

    #[test]
    fn stale_segments_dropped_quietly() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 7);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p1.clone(), 1);
        for s in split_into_segments(1, &c1.bytes, 64) {
            assert_eq!(st.on_segment(s).unwrap(), false);
        }
        assert!(!st.is_staged(1));
    }

    #[test]
    #[should_panic(expected = "safe point")]
    fn commit_mid_generation_panics() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 8);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0, 0);
        st.stage_checkpoint(c1);
        st.set_generating(true);
        st.commit(1);
    }

    #[test]
    fn commit_mid_generation_batch_is_deferred_to_the_safe_point() {
        // The pipelined runtime's invariant: a Commit arriving while a
        // generation batch runs must never apply under `generating == true`;
        // it parks and lands at the next inter-batch safe point.
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 21);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0.clone(), 0);
        st.set_generating(true);
        for s in split_into_segments(1, &c1.bytes, 64) {
            st.on_segment(s).unwrap(); // staging is allowed mid-generation
        }
        assert!(st.is_staged(1));
        assert_eq!(st.request_commit(1), CommitResult::Deferred);
        assert!(st.has_pending_commit());
        assert_eq!(st.active_version(), 0, "never applied mid-batch");
        assert_eq!(st.params(), &p0, "policy untouched mid-batch");
        assert_eq!(st.on_safe_point(), None, "still generating: no safe point");
        st.set_generating(false);
        assert_eq!(st.on_safe_point(), Some((1, CommitResult::Applied)));
        assert_eq!(st.active_version(), 1);
        assert_eq!(st.params(), &p1, "bit-exact at the safe point");
        assert!(!st.has_pending_commit());
        assert_eq!(st.on_safe_point(), None, "one-shot");
    }

    #[test]
    fn deferred_commit_supersedes_and_chains() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 22);
        let p2 = perturbed(&p1, 23);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let c2 = ckpt(&l, &p1, &p2, 1, 2);
        let mut st = PolicyState::new(l, p0, 0);
        st.set_generating(true);
        st.stage_checkpoint(c1);
        st.stage_checkpoint(c2);
        assert_eq!(st.request_commit(1), CommitResult::Deferred);
        assert_eq!(st.request_commit(2), CommitResult::Deferred);
        st.set_generating(false);
        // The newest request wins and chains through v1.
        assert_eq!(st.on_safe_point(), Some((2, CommitResult::Applied)));
        assert_eq!(st.active_version(), 2);
        assert_eq!(st.params(), &p2);
    }

    #[test]
    fn commit_overtaking_striped_segments_parks_until_staged() {
        // Multi-path delivery (striped WAN streams, relay forwarding) can
        // reorder a Commit(v) ahead of D_v's last segments. The commit
        // must park — not fail — and land when staging completes.
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 31);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0.clone(), 0);
        let segs = split_into_segments(1, &c1.bytes, 64);
        // Only the first half of the stream has arrived when Commit lands.
        for s in &segs[..segs.len() / 2] {
            st.on_segment(s.clone()).unwrap();
        }
        assert_eq!(st.request_commit(1), CommitResult::Deferred);
        assert!(st.has_pending_commit());
        assert_eq!(st.on_safe_point(), None, "segments still in flight: stay parked");
        assert!(st.has_pending_commit(), "parked commit survives the retry");
        assert_eq!(st.active_version(), 0);
        // The stragglers arrive (out of order) and the commit lands.
        for s in segs[segs.len() / 2..].iter().rev() {
            st.on_segment(s.clone()).unwrap();
        }
        assert_eq!(st.on_safe_point(), Some((1, CommitResult::Applied)));
        assert_eq!(st.active_version(), 1);
        assert_eq!(st.params(), &p1, "bit-exact despite the overtaken commit");
        assert!(!st.has_pending_commit());
    }

    #[test]
    fn commit_parks_while_an_intermediate_delta_is_still_in_flight() {
        // Whole deltas can reorder, not just segments: a small D_2 on fast
        // stripes completes while D_1 is still streaming. A parked
        // Commit(2) must wait for the full chain, then apply through it —
        // not consume the request and die on BaseMismatch.
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 41);
        let p2 = perturbed(&p1, 42);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let c2 = ckpt(&l, &p1, &p2, 1, 2);
        let mut st = PolicyState::new(l, p0, 0);
        st.stage_checkpoint(c2); // D_2 fully staged first
        assert_eq!(st.request_commit(1), CommitResult::Deferred);
        assert_eq!(st.request_commit(2), CommitResult::Deferred);
        assert_eq!(st.on_safe_point(), None, "D_1 still in flight: stay parked");
        assert!(st.has_pending_commit(), "request survives the retry");
        // D_1's segments land (out of order) and the chain applies.
        let segs = split_into_segments(1, &c1.bytes, 64);
        for s in segs.iter().rev() {
            st.on_segment(s.clone()).unwrap();
        }
        assert_eq!(st.on_safe_point(), Some((2, CommitResult::Applied)));
        assert_eq!(st.active_version(), 2);
        assert_eq!(st.params(), &p2);
    }

    #[test]
    fn commit_before_any_segment_parks_too() {
        // The extreme reorder: Commit(v) beats every segment of D_v (no
        // staging decoder exists yet). It must still park, not fail.
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 32);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0, 0);
        assert_eq!(st.request_commit(1), CommitResult::Deferred);
        for s in split_into_segments(1, &c1.bytes, 64) {
            st.on_segment(s).unwrap();
        }
        assert_eq!(st.on_safe_point(), Some((1, CommitResult::Applied)));
        assert_eq!(st.params(), &p1);
    }

    #[test]
    fn request_commit_at_safe_point_applies_immediately() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 24);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0, 0);
        st.stage_checkpoint(c1);
        assert_eq!(st.request_commit(1), CommitResult::Applied);
        assert_eq!(st.params(), &p1);
    }

    #[test]
    fn poisoned_staging_recovers_via_clean_retransmit() {
        // A corrupt stream poisons its decoder; the decoder must be
        // discarded so a full clean retransmit can restage the version
        // (parity with the legacy Reassembler recovery path).
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 11);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0, 0);
        let segs = split_into_segments(1, &c1.bytes, 64);
        let mut bad = segs[0].clone();
        bad.payload[0] ^= 0xFF; // break the stream header magic
        assert!(st.on_segment(bad).is_err());
        assert!(!st.is_staged(1));
        for s in &segs {
            st.on_segment(s.clone()).unwrap();
        }
        assert!(st.is_staged(1));
        assert_eq!(st.commit(1), CommitResult::Applied);
        assert_eq!(st.params(), &p1);
    }

    #[test]
    fn corrupt_staging_detected_at_segment_level() {
        let (l, p0) = setup();
        let p1 = perturbed(&p0, 9);
        let c1 = ckpt(&l, &p0, &p1, 0, 1);
        let mut st = PolicyState::new(l, p0, 0);
        let mut segs = split_into_segments(1, &c1.bytes, 64);
        // Corrupt one payload byte; reassembly completes but the sha check
        // in into_checkpoint must fail -> error surfaces on last segment.
        let n = segs.len();
        segs[n / 2].payload[0] ^= 0xFF;
        let mut failed = false;
        for s in segs {
            if st.on_segment(s).is_err() {
                failed = true;
            }
        }
        assert!(failed);
        assert!(!st.is_staged(1));
    }
}
