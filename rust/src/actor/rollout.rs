//! Rollout generation: autoregressive sampling through the AOT policy
//! artifact (the vLLM stand-in — PJRT executes the Pallas-attention
//! forward; rust does sampling, stopping, and batching).

use crate::data::{EOS, PAD};
use crate::delta::ParamSet;
use crate::runtime::Engines;
use crate::util::Rng;
use anyhow::Result;

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    /// 0.0 = greedy; otherwise softmax temperature.
    pub temperature: f32,
    pub max_new_tokens: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { temperature: 0.7, max_new_tokens: 16 }
    }
}

/// Output of one generation call for one prompt row.
#[derive(Clone, Debug)]
pub struct Generation {
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
}

/// Generate completions for up to `b_gen` prompts in one fixed-shape batch.
///
/// Prompts longer than `max_seq - 1` are truncated; generation stops per
/// row at EOS or when the row fills. Rows beyond `prompts.len()` are
/// padding and ignored.
pub fn generate_batch(
    eng: &Engines,
    policy: &ParamSet,
    prompts: &[Vec<i32>],
    cfg: SampleCfg,
    rng: &mut Rng,
) -> Result<Vec<Generation>> {
    let b = eng.manifest.b_gen;
    let t = eng.manifest.max_seq;
    let v = eng.manifest.vocab;
    assert!(prompts.len() <= b, "{} prompts > b_gen {b}", prompts.len());
    let mut tokens = vec![PAD; b * t];
    let mut lens = vec![0usize; b];
    for (r, p) in prompts.iter().enumerate() {
        let l = p.len().min(t - 1);
        tokens[r * t..r * t + l].copy_from_slice(&p[..l]);
        lens[r] = l;
    }
    let prompt_lens = lens.clone();
    let mut done = vec![false; b];
    for r in prompts.len()..b {
        done[r] = true;
    }
    for _ in 0..cfg.max_new_tokens {
        if done.iter().all(|&d| d) {
            break;
        }
        let logits = eng.policy_logits(policy, &tokens)?;
        for r in 0..prompts.len() {
            if done[r] || lens[r] >= t {
                done[r] = true;
                continue;
            }
            let pos = lens[r] - 1; // logits at the last filled position
            let row = &logits[(r * t + pos) * v..(r * t + pos + 1) * v];
            let next = sample_token(row, cfg.temperature, rng);
            tokens[r * t + lens[r]] = next;
            lens[r] += 1;
            if next == EOS {
                done[r] = true;
            }
        }
    }
    Ok((0..prompts.len())
        .map(|r| Generation {
            prompt_len: prompt_lens[r],
            tokens: tokens[r * t..r * t + lens[r]].to_vec(),
        })
        .collect())
}

/// Sample one token id from a logit row.
pub fn sample_token(logits: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    // Stable softmax sampling at the given temperature.
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut cum = Vec::with_capacity(logits.len());
    let mut total = 0.0f64;
    for &x in logits {
        total += (((x - max) / temperature) as f64).exp();
        cum.push(total);
    }
    let u = rng.f64() * total;
    match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
        Ok(i) | Err(i) => (i.min(logits.len() - 1)) as i32,
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(1);
        let logits = [0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates_on_max() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample_token(&logits, 0.3, &mut rng) == 1)
            .count();
        assert!(hits > 190, "{hits}");
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut rng = Rng::new(3);
        let logits = [1.0f32, 1.2, 0.9, 1.1];
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[sample_token(&logits, 5.0, &mut rng) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 500), "{counts:?}");
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let a: Vec<i32> = {
            let mut rng = Rng::new(42);
            (0..32).map(|_| sample_token(&logits, 1.0, &mut rng)).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(42);
            (0..32).map(|_| sample_token(&logits, 1.0, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}
