//! Sparsity experiments (need `make artifacts`): Figure 3 (rho across
//! models), Figure 4 (sparsity + reward across training), Table 4 (rho
//! under GRPO / RLOO / OPO). All measure the *real* mechanism: one RL step
//! through the PJRT train-step artifact, bf16 policy diffed by the real
//! extractor.

use super::print_table;
use crate::config;
use crate::rt::RunReport;
use crate::session::{RunSpec, Session};
use crate::trainer::Algorithm;
use crate::util::cli::Args;
use crate::util::fmt_bytes;
use anyhow::Result;

/// Build + run a spec to completion (sequential reference executor).
fn run_spec(spec: RunSpec) -> Result<RunReport> {
    Session::start(&spec.build()?)?.join()
}

fn artifact_models(args: &Args) -> Vec<String> {
    let spec = args.str_or("models", "sparrow-xs,sparrow-s");
    spec.split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().to_string())
        .filter(|m| {
            let ok = crate::runtime::artifacts_dir()
                .join(format!("{m}_policy_fwd.hlo.txt"))
                .exists();
            if !ok {
                eprintln!("skipping {m}: artifacts missing (make artifacts MODELS={m})");
            }
            ok
        })
        .collect()
}

/// Figure 3: nonzero update ratio after one RL step, across models.
/// Runnable models are *measured* end-to-end; the paper's models are
/// listed with their reported values for comparison.
pub fn fig3(args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for m in artifact_models(args) {
        let report = run_spec(
            RunSpec::model(&m)
                .steps(args.parse_or("steps", 3u64))
                .sft_steps(args.parse_or("sft-steps", 20u64))
                .lr_rl(1e-6)
                .seed(args.parse_or("seed", 0u64)),
        )?;
        let spec = config::model(&m).unwrap();
        rows.push(vec![
            format!("{m} (measured)"),
            format!("{}", spec.total_params()),
            format!("{:.2}%", report.mean_rho() * 100.0),
            fmt_bytes(report.steps.last().unwrap().payload_bytes),
            format!(
                "{}x",
                spec.dense_bytes_bf16() / report.steps.last().unwrap().payload_bytes.max(1)
            ),
        ]);
    }
    for m in ["qwen3-4b", "llama3-8b", "glm4-9b", "qwen2.5-72b"] {
        let spec = config::model(m).unwrap();
        rows.push(vec![
            format!("{m} (paper)"),
            format!("{}", spec.total_params()),
            format!("{:.2}%", spec.expected_rho * 100.0),
            "-".to_string(),
            "-".to_string(),
        ]);
    }
    print_table(
        "Figure 3: nonzero parameter-update ratio after one RL step (lr=1e-6)",
        &["Model", "Params", "rho", "Delta payload", "vs dense"],
        &rows,
    );
    Ok(())
}

/// Figure 4: sparsity and reward across RL training steps.
pub fn fig4(args: &Args) -> Result<()> {
    let model = args.str_or("model", "sparrow-xs");
    let steps = args.parse_or("steps", 40u64);
    let sft_steps = args.parse_or("sft-steps", 150u64);
    let lr_rl = args.parse_or("lr-rl", 2e-5f32);
    println!(
        "== Figure 4: training dynamics ({model}, {sft_steps} SFT + {steps} RL steps, lr_rl={lr_rl}) =="
    );
    let report = run_spec(
        RunSpec::model(&model)
            .steps(steps)
            .sft_steps(sft_steps)
            .lr_sft(args.parse_or("lr-sft", 5e-3f32))
            .lr_rl(lr_rl)
            .seed(args.parse_or("seed", 0u64))
            .verbose(),
    )?;
    println!(
        "\nSFT loss: {:.3} -> {:.3} over {} steps",
        report.sft_losses.first().copied().unwrap_or(0.0),
        report.sft_losses.last().copied().unwrap_or(0.0),
        report.sft_losses.len()
    );
    // Compact series (the figure's raw data).
    println!("\nstep, rho_pct, mean_reward, loss");
    for s in &report.steps {
        println!(
            "{}, {:.4}, {:.3}, {:.4}",
            s.step,
            s.rho * 100.0,
            s.mean_reward,
            s.loss
        );
    }
    let first_half: f32 = report.steps[..report.steps.len() / 2]
        .iter()
        .map(|s| s.mean_reward)
        .sum::<f32>()
        / (report.steps.len() / 2).max(1) as f32;
    println!(
        "\nmean rho {:.3}% (stable: min {:.3}%, max {:.3}%); reward {:.3} (first half) -> {:.3} (last quarter); wall {:.1}s",
        report.mean_rho() * 100.0,
        report.steps.iter().map(|s| s.rho).fold(1.0, f64::min) * 100.0,
        report.steps.iter().map(|s| s.rho).fold(0.0, f64::max) * 100.0,
        first_half,
        report.mean_reward_last_quarter(),
        report.wall_s,
    );
    println!("(paper: rho falls below 1% and stays there across 800 steps while reward rises)");
    Ok(())
}

/// Table 4: rho under GRPO vs RLOO vs OPO (same model, same data).
pub fn table4(args: &Args) -> Result<()> {
    let model = args.str_or("model", "sparrow-xs");
    let mut rows = Vec::new();
    for alg in Algorithm::all() {
        let report = run_spec(
            RunSpec::model(&model)
                .algorithm(alg)
                .steps(args.parse_or("steps", 3u64))
                .sft_steps(args.parse_or("sft-steps", 20u64))
                .lr_rl(1e-6)
                .seed(args.parse_or("seed", 0u64)),
        )?;
        rows.push(vec![
            alg.name().to_string(),
            format!("{:.2}%", report.mean_rho() * 100.0),
        ]);
    }
    print_table(
        &format!("Table 4: nonzero ratio by RL algorithm ({model}, lr=1e-6)"),
        &["Algorithm", "rho"],
        &rows,
    );
    println!("(paper, Qwen3-8B: GRPO 0.96%, RLOO 0.93%, OPO 1.06%)");
    Ok(())
}
