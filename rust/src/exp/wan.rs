//! Multi-region WAN distribution experiment (`sparrowrl exp wan`): the
//! paper's 1–4-region scaling story (§7.5, Fig 13) rebuilt on the
//! bandwidth-aware distribution tree.
//!
//! Two sections:
//! * **Scaling table** — for each `wan-1..4` preset: the analytic
//!   [`DistributionPlan`] delivery makespan (striped relay tree) vs the
//!   single-stream direct per-actor fan-out baseline, end-to-end
//!   throughput from the simulator with the bandwidth-aware gate on, and
//!   tokens-per-dollar (on-demand cross-cloud incl. egress vs reserved
//!   RDMA).
//! * **Runtime section** — the real pipelined runtime on the 4-region
//!   preset, artifact-free (`SyntheticCompute`): hub streams segments to
//!   one relay worker per region, relays forward to peers; reports
//!   per-region WAN ingress payload, run makespan, and the measured
//!   overlap (hidden-sync) ratio.

use super::print_table;
use crate::config::{self, wan_preset, GpuClass};
use crate::cost::{table6_deployments, wan_deployment};
use crate::data::Benchmark;
use crate::metrics::SpanKind;
use crate::rt::SyntheticCompute;
use crate::session::{RunSpec, Session};
use crate::sim::compute::{delta_payload_bytes, ComputeModel};
use crate::sim::driver::{run as sim_run, SimConfig};
use crate::sim::{RegionSpec, System};
use crate::transport::DistributionPlan;
use crate::util::cli::Args;
use crate::util::{fmt_bytes, Rng};
use anyhow::Result;
use std::time::Duration;

/// Analytic + simulated scaling rows for `wan-1..wan-n` presets.
pub fn scaling_rows(model_name: &str, max_regions: usize, seed: u64) -> Result<Vec<Vec<String>>> {
    let model = config::model(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
    let payload = delta_payload_bytes(&model, model.expected_rho);
    let mut rows = Vec::new();
    for n in 1..=max_regions {
        let preset = wan_preset(&format!("wan-{n}")).expect("wan preset");
        let plan = DistributionPlan::from_preset(&preset, 1 << 20);
        let mut rng = Rng::new(seed);
        let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
        let produce = Some(cm.stream_emit_bps(&model, payload));
        let striped = plan.makespan(payload, produce, &mut rng);
        let direct = plan.direct_single_stream_makespan(payload, produce, &mut rng);

        // End-to-end throughput: the sim driver over the same regions,
        // relay fanout + bandwidth-aware gate on.
        let fleet: Vec<RegionSpec> = preset
            .regions
            .iter()
            .map(|r| RegionSpec::new(*r, vec![GpuClass::A100; preset.actors_per_region]))
            .collect();
        let mut cfg =
            SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, System::Sparrow, fleet);
        // The sim takes one global stream count; the max across legs is
        // numerically identical per leg to BDP sizing, because
        // `Link::effective_bps` caps at the leg's capacity — extra streams
        // past a link's own BDP count change nothing on that link.
        cfg.streams = plan.legs.iter().map(|l| l.streams).max().unwrap_or(4);
        cfg.bandwidth_gate = true;
        cfg.seed = seed;
        let sim = sim_run(&cfg);

        let cross = wan_deployment(n, preset.actors_per_region);
        let tpd = cross.tokens_per_dollar_with_egress(
            sim.throughput(),
            payload * n as u64,
            sim.avg_step_time().max(1e-9),
        );
        let rdma_tpd = table6_deployments(model_name)
            .map(|(_, rdma)| rdma.tokens_per_dollar(sim.throughput()));
        rows.push(vec![
            preset.name.to_string(),
            format!("{}", preset.n_actors()),
            fmt_bytes(payload),
            format!("{striped:.2}s"),
            format!("{direct:.2}s"),
            format!("{:.1}x", direct / striped.max(1e-9)),
            format!("{:.0}", sim.throughput()),
            format!("{:.2}M", tpd / 1e6),
            rdma_tpd
                .map(|r| format!("{:.2}x", tpd / r))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    Ok(rows)
}

/// The `exp wan` entry point.
pub fn wan(args: &Args) -> Result<()> {
    let model_name = args.str_or("model", "qwen3-8b");
    let seed = args.parse_or("seed", 0u64);

    // --- Section A: 1-4 region scaling -----------------------------------
    let rows = scaling_rows(&model_name, 4, seed)?;
    print_table(
        &format!("WAN scaling ({model_name}): striped relay tree vs 1-stream direct fan-out"),
        &[
            "Preset", "Actors", "Payload", "Tree", "Direct", "Speedup", "tok/s",
            "tok/$", "vs RDMA",
        ],
        &rows,
    );
    println!("(paper Fig 13: SparrowRL loses only ~13.7% from 1-DC to 4-DC; Full loses 5.86x)");

    // Per-region utilization on the widest preset.
    let model = config::model(&model_name).unwrap();
    let payload = delta_payload_bytes(&model, model.expected_rho);
    let preset = wan_preset("wan-4").unwrap();
    let plan = DistributionPlan::from_preset(&preset, 1 << 20);
    let mut rng = Rng::new(seed);
    let mk = plan.makespan(payload, None, &mut rng);
    let util_rows: Vec<Vec<String>> = plan
        .region_utilization(payload, mk)
        .into_iter()
        .zip(plan.legs.iter())
        .map(|((region, util), leg)| {
            vec![
                region,
                format!("{}", leg.streams),
                fmt_bytes(payload),
                format!("{:.0}%", util * 100.0),
            ]
        })
        .collect();
    print_table(
        &format!("wan-4 per-region WAN legs (makespan {mk:.2}s)"),
        &["Region", "Stripes", "Ingress/step", "Utilization"],
        &util_rows,
    );

    // --- Section B: the real pipelined runtime over the 4-region tree ----
    // `RunSpec::wan` derives the same relay tree `plan` describes (and
    // the fleet size, and the pipelined coercion) inside `build()`.
    let steps = args.parse_or("steps", 5u64);
    let layout = crate::delta::ModelLayout::transformer("syn-wan", 512, 128, 2, 256);
    let comp = SyntheticCompute::new(16, 8, 64)
        .with_delays(Duration::from_millis(8), Duration::from_millis(6));
    let run_plan = RunSpec::synthetic()
        .wan("wan-4")
        .steps(steps)
        .sft_steps(0)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .seed(seed)
        .build()?;
    let report = Session::start_with_compute(&run_plan, layout, comp)?.join()?;
    let sync = [SpanKind::Train, SpanKind::Extract];
    let per_step_payload =
        report.steps.iter().map(|s| s.payload_bytes).sum::<u64>() / report.steps.len().max(1) as u64;
    let region_rows: Vec<Vec<String>> = plan
        .legs
        .iter()
        .map(|leg| {
            vec![
                leg.region.clone(),
                format!("{}", 1 + leg.peers.len()),
                format!("actor{}", leg.relay),
                fmt_bytes(per_step_payload),
                fmt_bytes(per_step_payload * (1 + leg.peers.len()) as u64),
            ]
        })
        .collect();
    print_table(
        &format!(
            "Pipelined runtime over wan-4 (SyntheticCompute, {} actors): makespan {:.2}s, \
             overlap {:.0}%, {} versions",
            plan.n_actors(),
            report.wall_s,
            report.timeline.overlap_ratio("trainer", &sync) * 100.0,
            report.final_version,
        ),
        &["Region", "Actors", "Relay", "WAN ingress/step", "Direct would ship"],
        &region_rows,
    );
    println!(
        "relay tree ships {} per region per step; direct fan-out would ship one copy per actor",
        fmt_bytes(per_step_payload),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wan_experiment_runs_artifact_free() {
        let args = Args::parse(vec!["--steps".to_string(), "3".to_string()]);
        wan(&args).unwrap();
    }

    #[test]
    fn scaling_rows_cover_all_presets_and_tree_wins() {
        let rows = scaling_rows("qwen3-8b", 4, 0).unwrap();
        assert_eq!(rows.len(), 4);
        for row in &rows {
            // Speedup column is "N.Nx" with N >= 1.
            let speedup: f64 = row[5].trim_end_matches('x').parse().unwrap();
            assert!(speedup >= 1.0, "{}: striped tree must not lose", row[0]);
        }
    }
}
