//! Transfer-cost experiments: Table 2 (bandwidth barrier), Figure 10
//! (encoding ablation), Figure 12 (tc-style bandwidth sweep).

use super::print_table;
use crate::config::{self, regions};
use crate::data::Benchmark;
use crate::delta::{encode_delta, naive, ApplyMode, SparseDelta, TensorDelta};
use crate::netsim::Link;
use crate::sim::compute::{delta_payload_bytes, naive_payload_bytes, ComputeModel};
use crate::transport::plan::TransferPlan;
use crate::util::cli::Args;
use crate::util::{fmt_bytes, fmt_secs, prop, Bf16, Rng};
use anyhow::Result;

/// Table 2: full-model sync time for Qwen3-8B on HPC vs commodity links.
pub fn table2(_args: &Args) -> Result<()> {
    let model = config::model("qwen3-8b").unwrap();
    let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
    let bytes = model.dense_bytes_bf16();
    let cases = [
        ("HPC fabric (RDMA)", Link::emulated(100e9, 0.000_05, 0.0)),
        ("Commodity network", Link::emulated(1e9, 0.030, 0.0)),
    ];
    let mut rows = Vec::new();
    for (name, link) in cases {
        // Table 2 divides payload by line rate (saturating bulk transfer).
        let t = link.startup_time() + bytes as f64 * 8.0 / link.capacity_bps;
        rows.push(vec![
            name.to_string(),
            format!("{:.0} s", cm.train_time(&model, crate::sim::compute::TRAIN_ANCHOR_TOKENS)),
            "45 s".to_string(),
            format!("{:.0} Gbps", link.capacity_bps / 1e9),
            fmt_secs(t),
        ]);
    }
    print_table(
        "Table 2: full-model synchronization, Qwen3-8B (16 GB bf16)",
        &["Network", "Trainer", "Actor", "BW", "Sync"],
        &rows,
    );
    println!("(paper: 1.3 s on 100 Gbps RDMA; 128 s on 1 Gbps commodity)");
    Ok(())
}

/// Build a real sparse delta at density `rho` over `n` elements and return
/// measured (varint bytes, naive bytes) per nnz using the actual codecs.
pub fn measured_bytes_per_nnz(n: u64, rho: f64, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let k = ((n as f64 * rho) as usize).max(1);
    let layout = crate::delta::ModelLayout::new(
        "sample",
        vec![crate::delta::TensorSpec::new("w", &[n as usize])],
    );
    let idx = prop::sparse_indices(&mut rng, n, k);
    let vals: Vec<Bf16> = (0..k).map(|_| Bf16::from_bits(rng.next_u64() as u16)).collect();
    let d = SparseDelta {
        version: 1,
        base_version: 0,
        model_fp: layout.fingerprint(),
        mode: ApplyMode::Assign,
        tensors: vec![TensorDelta { tensor: 0, idx, vals }],
    };
    let varint = encode_delta(&d).len() as f64 / k as f64;
    let naive = naive::encode_naive(&d, &layout).len() as f64 / k as f64;
    (varint, naive)
}

/// Figure 10: per-step delta encoding + transfer cost for Qwen3-8B over
/// the US-Canada link. Payloads extrapolate the *measured* bytes/nnz of
/// the real codec (sampled at 64M elements) to the 8B model.
pub fn fig10(args: &Args) -> Result<()> {
    let model = config::model("qwen3-8b").unwrap();
    let rho = model.expected_rho;
    let sample_n: u64 = args.parse_or("sample-elems", 1u64 << 26);
    let (varint_per, naive_per) = measured_bytes_per_nnz(sample_n, rho, 7);
    let nnz = model.total_params() as f64 * rho;
    let varint_bytes = (nnz * varint_per) as u64;
    let naive_bytes = (nnz * naive_per) as u64;
    let link = Link::from_profile(&regions::CANADA);
    let mut rng = Rng::new(0);
    let single = TransferPlan::single_stream();
    let multi = TransferPlan::sparrow_default();
    let rows = vec![
        (
            "naive int32 (single stream)",
            naive_bytes,
            single.delivery_time(&link, naive_bytes, None, &mut rng),
        ),
        (
            "varint delta (single stream)",
            varint_bytes,
            single.delivery_time(&link, varint_bytes, None, &mut rng),
        ),
        (
            "varint delta + MS (4 streams)",
            varint_bytes,
            multi.delivery_time(&link, varint_bytes, None, &mut rng),
        ),
    ]
    .into_iter()
    .map(|(name, b, t)| vec![name.to_string(), fmt_bytes(b), fmt_secs(t)])
    .collect::<Vec<_>>();
    print_table(
        &format!(
            "Figure 10: per-step delta transfer, Qwen3-8B US-Canada (rho={:.2}%, codec measured at {} elems: {:.2} B/nnz varint, {:.2} B/nnz naive)",
            rho * 100.0, sample_n, varint_per, naive_per
        ),
        &["Encoding", "Payload", "Transfer"],
        &rows,
    );
    println!("(paper: 414 MB / 9.22 s naive; 202 MB / 4.71 s varint; 2.90 s +MS)");
    Ok(())
}

/// Figure 12: per-step weight transfer time under emulated bandwidth
/// (0.25-10 Gbps), Full vs Delta, for 4B/8B/14B.
pub fn fig12(args: &Args) -> Result<()> {
    let bws: Vec<f64> = args.list_or("bw", &[0.25, 0.5, 1.0, 2.5, 5.0, 10.0]);
    let models = ["qwen3-4b", "qwen3-8b", "qwen3-14b"];
    let mut rng = Rng::new(0);
    let mut rows = Vec::new();
    for name in models {
        let model = config::model(name).unwrap();
        let dense = model.dense_bytes_bf16();
        let delta = delta_payload_bytes(&model, model.expected_rho);
        for &gbps in &bws {
            // tc-style emulation: clean link at the shaped rate, WAN RTT.
            let link = Link::emulated(gbps * 1e9, 0.030, 0.0);
            let t_full = TransferPlan::full_weight().delivery_time(&link, dense, None, &mut rng);
            let t_delta =
                TransferPlan::sparrow_default().delivery_time(&link, delta, None, &mut rng);
            rows.push(vec![
                name.to_string(),
                format!("{gbps} Gbps"),
                fmt_secs(t_full),
                fmt_secs(t_delta),
                format!("{:.0}x", t_full / t_delta),
            ]);
        }
    }
    print_table(
        "Figure 12: per-step transfer time under emulated bandwidth (tc)",
        &["Model", "BW", "Full", "Delta", "Reduction"],
        &rows,
    );
    println!("(paper anchors: 8B Full 566 s @ 250 Mbps, 17.3 s @ 10 Gbps; Delta 0.25 s @ 10 Gbps)");
    // Sanity anchor for the naive-payload comparison.
    let m8 = config::model("qwen3-8b").unwrap();
    println!(
        "analytic payloads 8B: dense {} | varint {} | naive {}",
        fmt_bytes(m8.dense_bytes_bf16()),
        fmt_bytes(delta_payload_bytes(&m8, m8.expected_rho)),
        fmt_bytes(naive_payload_bytes(&m8, m8.expected_rho)),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_codec_rates_sane_at_one_percent() {
        let (varint, naive) = measured_bytes_per_nnz(1 << 20, 0.01, 3);
        // ~2B value + ~1.3B index (+framing) vs 6B fixed.
        assert!((3.0..3.8).contains(&varint), "varint {varint:.2} B/nnz");
        assert!((5.9..6.3).contains(&naive), "naive {naive:.2} B/nnz");
    }

    #[test]
    fn experiments_run_clean() {
        let args = Args::parse(vec!["--sample-elems".into(), "1048576".into()]);
        table2(&args).unwrap();
        fig10(&args).unwrap();
        fig12(&args).unwrap();
    }
}
