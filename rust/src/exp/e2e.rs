//! End-to-end simulation experiments: Figures 8, 9, 11, 13 and Tables 5,
//! 6, 7 — all driven by the same `sim` engine + the §7.1 testbed presets.

use super::print_table;
use crate::config::{self, regions, GpuClass, ModelSpec};
use crate::cost::table6_deployments;
use crate::data::Benchmark;
use crate::metrics::{geometric_mean, SpanKind};
use crate::rt::{ExecMode, RunReport, SyntheticCompute};
use crate::session::{RunSpec, Session};
use crate::sim::driver::{run, SimConfig};
use crate::sim::{RegionSpec, System};
use crate::util::cli::Args;
use crate::util::{fmt_bytes, fmt_secs};
use anyhow::Result;
use std::time::Duration;

/// The paper's fleet for a model size: 4/8/12 A100 actors in Canada,
/// 2/4/6-ish trainer H100s (capacity-matched, §7.1).
fn paper_fleet(model: &ModelSpec) -> Vec<RegionSpec> {
    let n = ((model.total_params() as f64 / 1.02e9).round() as usize).clamp(4, 16);
    vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; n])]
}

fn testbed(model: &str, bench: Benchmark, system: System) -> SimConfig {
    let model = config::model(model).unwrap();
    let fleet = paper_fleet(&model);
    SimConfig::paper_testbed(model, bench, system, fleet)
}

/// Figure 8: throughput + step time across benchmarks, model sizes, and
/// systems.
pub fn fig8(_args: &Args) -> Result<()> {
    let mut thr_rows = Vec::new();
    let mut step_rows = Vec::new();
    for bench in Benchmark::all() {
        for m in config::paper_models() {
            let mut thr = vec![format!("{}/{}", bench.name(), m)];
            let mut step = vec![format!("{}/{}", bench.name(), m)];
            let mut sparrow = 0.0;
            let mut full = 0.0;
            let mut ideal = 0.0;
            for sys in System::all() {
                let r = run(&testbed(m, bench, sys));
                thr.push(format!("{:.0}", r.throughput()));
                step.push(format!("{:.0}", r.avg_step_time()));
                match sys {
                    System::Sparrow => sparrow = r.throughput(),
                    System::PrimeRlFull => full = r.throughput(),
                    System::IdealSingleDc => ideal = r.throughput(),
                    _ => {}
                }
            }
            thr.push(format!("{:.1}x", sparrow / full));
            thr.push(format!("{:.2}%", (1.0 - sparrow / ideal) * 100.0));
            thr_rows.push(thr);
            step_rows.push(step);
        }
    }
    let hdr = ["Workload", "Ideal-1DC", "SparrowRL", "PrimeRL-MS", "PrimeRL-Full", "Sp/Full", "gap to ideal"];
    print_table("Figure 8(a): end-to-end throughput (tokens/s)", &hdr, &thr_rows);
    print_table(
        "Figure 8(b): average step time (s)",
        &["Workload", "Ideal-1DC", "SparrowRL", "PrimeRL-MS", "PrimeRL-Full"],
        &step_rows,
    );
    println!("(paper: speedups 2.4-3.7x @4B to 7.7-9.5x @14B; gap to ideal 1.31-8.91%)");
    Ok(())
}

/// Figure 9: five-step execution timeline, PrimeRL-Full vs SparrowRL.
pub fn fig9(args: &Args) -> Result<()> {
    let width = args.parse_or("width", 100usize);
    for sys in [System::PrimeRlFull, System::Sparrow] {
        let mut cfg = testbed("qwen3-8b", Benchmark::Gsm8k, sys);
        cfg.steps = 5;
        // A compact fleet keeps the Gantt readable.
        cfg.regions = vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; 4])];
        cfg.batch = cfg.batch.min(2000);
        let r = run(&cfg);
        println!(
            "\n== Figure 9 ({}): 5 steps in {} ==  [R rollout, T train, E extract, = transfer]",
            sys.name(),
            fmt_secs(r.total_time)
        );
        print!("{}", r.timeline.ascii_gantt(width));
        println!(
            "payload/step {}, avg transfer {}",
            fmt_bytes(r.payload_bytes()),
            fmt_secs(r.avg_transfer_time())
        );
    }
    println!("(paper: Full 15 min 48 s vs SparrowRL 5 min 9 s for 5 steps; payload 15.6 GB -> 202 MB)");
    Ok(())
}

/// Figure 11: single- vs multi-stream delta transfer, 8B/14B x 2 datasets.
/// Run in the online regime (small per-step batch => ~20 s generation
/// windows) where the transfer deadline actually binds; with very long
/// windows both variants hide completely and the e2e gain vanishes.
pub fn fig11(args: &Args) -> Result<()> {
    let window = args.parse_or("window", 20.0f64);
    let mut rows = Vec::new();
    for m in ["qwen3-8b", "qwen3-14b"] {
        for bench in [Benchmark::Gsm8k, Benchmark::DeepScaleR] {
            let mk = |streams: usize| {
                let mut cfg = testbed(m, bench, System::Sparrow);
                cfg.batch = (cfg.batch as f64 * window / SimConfig::TARGET_WINDOW_S) as u64;
                cfg.streams = streams;
                cfg.steps = 12;
                run(&cfg)
            };
            let single = mk(1);
            let multi = mk(4);
            let (ts, tm) = (single.throughput(), multi.throughput());
            rows.push(vec![
                m.to_string(),
                bench.name().to_string(),
                format!("{ts:.0}"),
                format!("{tm:.0}"),
                format!("+{:.1}%", (tm / ts - 1.0) * 100.0),
                format!(
                    "{} -> {}",
                    crate::util::fmt_secs(single.avg_transfer_time()),
                    crate::util::fmt_secs(multi.avg_transfer_time())
                ),
            ]);
        }
    }
    print_table(
        "Figure 11: throughput, single vs 4-stream delta transfer",
        &["Model", "Dataset", "1 stream", "4 streams", "gain", "transfer"],
        &rows,
    );
    println!("(paper: +8.2-11.7% @8B, +12.4-16.3% @14B)");
    Ok(())
}

/// Table 5: relay-based delta distribution on/off (Canada-Australia).
/// Run in the online regime (short windows) where fanout tails surface.
pub fn table5(args: &Args) -> Result<()> {
    let window = args.parse_or("window", 20.0f64);
    let mut rows = Vec::new();
    for bench in [Benchmark::Gsm8k, Benchmark::DeepScaleR] {
        let mk = |relay: bool| {
            let model = config::model("qwen3-8b").unwrap();
            let mut au = RegionSpec::new(regions::AUSTRALIA, vec![GpuClass::A100; 6]);
            au.use_relay = relay;
            let mut ca = RegionSpec::new(regions::CANADA, vec![GpuClass::A100; 2]);
            ca.use_relay = relay;
            let mut cfg =
                SimConfig::paper_testbed(model, bench, System::Sparrow, vec![ca, au]);
            cfg.batch = (cfg.batch as f64 * window / SimConfig::TARGET_WINDOW_S) as u64;
            cfg.steps = 12;
            cfg
        };
        let base = run(&mk(false)).throughput();
        let relay = run(&mk(true)).throughput();
        rows.push(vec![
            bench.name().to_string(),
            format!("{base:.1}"),
            format!("{relay:.1}"),
            format!("+{:.1}%", (relay / base - 1.0) * 100.0),
        ]);
    }
    print_table(
        "Table 5: relay-based delta distribution (Canada-Australia, Qwen3-8B)",
        &["Dataset", "Baseline", "Relay", "Improvement"],
        &rows,
    );
    println!("(paper: +4.4% GSM8K, +13.9% DeepScaleR)");
    Ok(())
}

/// Figure 13: throughput as actors span 1-4 geographic regions.
pub fn fig13(_args: &Args) -> Result<()> {
    let model = config::model("qwen3-4b").unwrap();
    let dcs = [
        regions::CANADA,
        regions::JAPAN,
        regions::NETHERLANDS,
        regions::ICELAND,
    ];
    let mut rows = Vec::new();
    let mut sparrow1 = 0.0;
    for n_dc in 1..=4usize {
        // 4 A100 actors spread across the first n regions.
        let mut fleets: Vec<RegionSpec> =
            dcs[..n_dc].iter().map(|r| RegionSpec::new(*r, vec![])).collect();
        for i in 0..4 {
            fleets[i % n_dc].gpus.push(GpuClass::A100);
        }
        let fleets: Vec<RegionSpec> =
            fleets.into_iter().filter(|f| !f.gpus.is_empty()).collect();
        let sparrow = run(&SimConfig::paper_testbed(
            model.clone(),
            Benchmark::Gsm8k,
            System::Sparrow,
            fleets.clone(),
        ))
        .throughput();
        let full = run(&SimConfig::paper_testbed(
            model.clone(),
            Benchmark::Gsm8k,
            System::PrimeRlFull,
            fleets,
        ))
        .throughput();
        if n_dc == 1 {
            sparrow1 = sparrow;
        }
        rows.push(vec![
            format!("{n_dc}-DC"),
            format!("{sparrow:.0}"),
            format!("{full:.0}"),
            format!("{:.1}x", sparrow / full),
            format!("{:+.1}%", (sparrow / sparrow1 - 1.0) * 100.0),
        ]);
    }
    print_table(
        "Figure 13: throughput vs geographic dispersion (Qwen3-4B, 4xA100)",
        &["DCs", "SparrowRL", "PrimeRL-Full", "Sp/Full", "Sparrow vs 1-DC"],
        &rows,
    );
    println!("(paper: Full drops 7137 -> 1219 tok/s (5.86x); Sparrow only -13.7%; Sp/Full 1.9-9x)");
    Ok(())
}

/// Table 6: cost efficiency vs the reserved RDMA cluster.
pub fn table6(_args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for m in ["qwen3-8b", "qwen3-14b"] {
        let model = config::model(m).unwrap();
        let (cross, single) = table6_deployments(m).unwrap();
        // Cross-cloud fleet: H100s train, A100 actors in Canada; SingleDC:
        // all-H100 RDMA. Throughput = geomean across the 3 benchmarks.
        let h100s = if m == "qwen3-8b" { 4 } else { 6 };
        let a100s = if m == "qwen3-8b" { 8 } else { 12 };
        let mut sp_thr = Vec::new();
        let mut dc_thr = Vec::new();
        for bench in Benchmark::all() {
            let fleet = vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; a100s])];
            let mut cfg =
                SimConfig::paper_testbed(model.clone(), bench, System::Sparrow, fleet);
            cfg.trainer_gpus = h100s;
            sp_thr.push(run(&cfg).throughput());
            // SingleDC: capacity-matched H100 fleet on RDMA.
            let dc_fleet = vec![RegionSpec::new(
                regions::US_LOCAL,
                vec![GpuClass::H100; a100s / 2],
            )];
            let mut dc_cfg =
                SimConfig::paper_testbed(model.clone(), bench, System::IdealSingleDc, dc_fleet);
            dc_cfg.trainer_gpus = h100s;
            dc_thr.push(run(&dc_cfg).throughput());
        }
        let sp = geometric_mean(&sp_thr);
        let dc = geometric_mean(&dc_thr);
        let sp_tpd = cross.tokens_per_dollar(sp);
        let dc_tpd = single.tokens_per_dollar(dc);
        rows.push(vec![
            m.to_string(),
            "SparrowRL".to_string(),
            cross.name.clone(),
            format!("{:.1}k", sp / 1e3),
            format!("{:.2}", cross.cost_per_hr()),
            format!("{:.2}M", sp_tpd / 1e6),
            format!("{:.2}x", sp_tpd / dc_tpd),
        ]);
        rows.push(vec![
            m.to_string(),
            "SingleDC".to_string(),
            single.name.clone(),
            format!("{:.1}k", dc / 1e3),
            format!("{:.2}", single.cost_per_hr()),
            format!("{:.2}M", dc_tpd / 1e6),
            "1.00x".to_string(),
        ]);
    }
    print_table(
        "Table 6: cost efficiency (geomean throughput across benchmarks)",
        &["Model", "Method", "Configuration", "GM tok/s", "$/hr", "tokens/$", "Norm."],
        &rows,
    );
    println!("(paper: 1.21x @8B, 1.59x @14B over reserved RDMA)");
    Ok(())
}

/// Overlapped one-step runtime: sequential vs pipelined executors on the
/// *real* loop (not the simulator). Uses PJRT artifacts when present,
/// otherwise the deterministic synthetic engine with emulated compute
/// latencies — either way the measured Rollout/Train/Extract spans land in
/// the report timeline, so the hidden-sync ratio is inspectable exactly
/// like the sim's Figure 9 trace.
pub fn overlap(args: &Args) -> Result<()> {
    let model = args.str_or("model", "sparrow-xs");
    let steps = args.parse_or("steps", 6u64);
    let width = args.parse_or("width", 100usize);
    let have_artifacts = crate::runtime::artifacts_dir()
        .join(format!("{model}_policy_fwd.hlo.txt"))
        .exists();
    let run_mode = |mode: ExecMode| -> Result<RunReport> {
        if have_artifacts {
            let plan = RunSpec::model(&model)
                .steps(steps)
                .sft_steps(args.parse_or("sft-steps", 10u64))
                .mode(mode)
                .build()?;
            Session::start(&plan)?.join()
        } else {
            let layout = crate::delta::ModelLayout::transformer("syn-overlap", 512, 128, 2, 256);
            let comp = SyntheticCompute::new(16, 8, 64)
                .with_delays(Duration::from_millis(8), Duration::from_millis(6));
            let plan = RunSpec::synthetic()
                .steps(steps)
                .sft_steps(0)
                .group_size(2)
                .max_new_tokens(6)
                .lr_rl(1e-2)
                .mode(mode)
                .build()?;
            Session::start_with_compute(&plan, layout, comp)?.join()
        }
    };
    if !have_artifacts {
        println!("(artifacts for {model} missing; measuring the synthetic engine)");
    }
    let seq = run_mode(ExecMode::Sequential)?;
    let pip = run_mode(ExecMode::Pipelined)?;
    let sync = [SpanKind::Train, SpanKind::Extract];
    let rows = vec![
        vec![
            "sequential".to_string(),
            format!("{:.2}s", seq.wall_s),
            format!("{:.0}%", seq.timeline.overlap_ratio("trainer", &sync) * 100.0),
            format!("{}", seq.final_version),
        ],
        vec![
            "pipelined".to_string(),
            format!("{:.2}s", pip.wall_s),
            format!("{:.0}%", pip.timeline.overlap_ratio("trainer", &sync) * 100.0),
            format!("{}", pip.final_version),
        ],
    ];
    print_table(
        "Overlapped one-step runtime: wall-clock + hidden synchronization",
        &["Executor", "Wall", "Hidden sync", "Versions"],
        &rows,
    );
    println!("speedup: {:.2}x", seq.wall_s / pip.wall_s.max(1e-9));
    println!("\npipelined timeline  [R rollout, T train, E extract, = transfer, | commit]");
    print!("{}", pip.timeline.ascii_gantt(width));
    Ok(())
}

/// Table 7: uniform vs heterogeneity-aware load balancing on a mixed
/// A100+L40 pool.
pub fn table7(_args: &Args) -> Result<()> {
    let model = config::model("qwen3-4b").unwrap();
    let mut rows = Vec::new();
    for bench in [Benchmark::Gsm8k, Benchmark::DeepScaleR] {
        let mk = |hetero: bool| {
            let pool = vec![
                GpuClass::A100,
                GpuClass::A100,
                GpuClass::A100,
                GpuClass::A100,
                GpuClass::L40,
                GpuClass::L40,
                GpuClass::L40,
                GpuClass::L40,
            ];
            let mut cfg = SimConfig::paper_testbed(
                model.clone(),
                bench,
                System::Sparrow,
                vec![RegionSpec::new(regions::CANADA, pool)],
            );
            cfg.trainer_gpus = 4;
            cfg.hetero_sched = hetero;
            cfg
        };
        let uniform = run(&mk(false)).throughput();
        let aware = run(&mk(true)).throughput();
        rows.push(vec![
            bench.name().to_string(),
            format!("{uniform:.1}"),
            format!("{aware:.1}"),
            format!("+{:.1}%", (aware / uniform - 1.0) * 100.0),
        ]);
    }
    print_table(
        "Table 7: uniform vs heterogeneity-aware scheduling (4xA100 + 4xL40)",
        &["Dataset", "Uniform", "Heterogeneity-aware", "Improvement"],
        &rows,
    );
    println!("(paper: +35.5% GSM8K, +26.4% DeepScaleR)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sim_experiments_run_clean() {
        let args = Args::parse(Vec::<String>::new());
        fig8(&args).unwrap();
        fig9(&args).unwrap();
        fig11(&args).unwrap();
        fig13(&args).unwrap();
        table5(&args).unwrap();
        table6(&args).unwrap();
        table7(&args).unwrap();
    }

    #[test]
    fn overlap_experiment_runs_without_artifacts() {
        let args = Args::parse(vec!["--steps".to_string(), "3".to_string()]);
        overlap(&args).unwrap();
    }
}
