//! Experiment harness: one target per table/figure in the paper's §7
//! (see DESIGN.md §4 for the index). Run via `sparrowrl exp <id>`.
//!
//! These targets reproduce the paper's *analytic* tables. Their
//! regression-gated counterpart is `sparrowrl bench` ([`crate::bench`]):
//! the scenario-matrix harness that runs real Session-API cells and
//! diffs the deterministic results against a committed baseline in CI.

pub mod e2e;
pub mod encoding;
pub mod sparsity;
pub mod wan;

use crate::util::cli::Args;
use anyhow::{bail, Result};

/// All experiment ids, in paper order (plus repo-specific extras).
pub const ALL: &[&str] = &[
    "table2", "fig3", "fig4", "table4", "fig8", "fig9", "fig10", "fig11",
    "table5", "fig12", "fig13", "table6", "table7", "overlap", "wan",
];

/// Dispatch one experiment by id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "table2" => encoding::table2(args),
        "fig10" => encoding::fig10(args),
        "fig12" => encoding::fig12(args),
        "fig3" => sparsity::fig3(args),
        "fig4" => sparsity::fig4(args),
        "table4" => sparsity::table4(args),
        "fig8" => e2e::fig8(args),
        "fig9" => e2e::fig9(args),
        "fig11" => e2e::fig11(args),
        "fig13" => e2e::fig13(args),
        "table5" => e2e::table5(args),
        "table6" => e2e::table6(args),
        "table7" => e2e::table7(args),
        "overlap" => e2e::overlap(args),
        "wan" => wan::wan(args),
        "all" => {
            for id in ALL {
                println!("\n################ {id} ################");
                run(id, args)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; known: {ALL:?} or 'all'"),
    }
}

/// Shared pretty-printer: a fixed-width table.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&header.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}
