//! Analytic timing of the streaming transfer protocol over `netsim` links.
//!
//! The discrete-event simulator and every transfer-time experiment
//! (Table 2, Figures 10–13) price transfers through this one model so that
//! baselines and SparrowRL differ only in the knobs the paper varies:
//! payload size, stream count, pipelining, and relay fanout.

use crate::netsim::{Link, TransferOpts};
use crate::util::Rng;

/// Default intra-region (same provider/datacenter LAN) path used for
/// relay → peer fanout: 10 Gbps, 1 ms RTT, clean.
pub fn intra_region_link() -> Link {
    Link::emulated(10e9, 0.001, 0.0)
}

/// How a checkpoint (or dense weight blob) is moved.
#[derive(Clone, Copy, Debug)]
pub struct TransferPlan {
    /// Parallel TCP streams (1 = the paper's single-stream baseline).
    pub streams: usize,
    /// Segment size for cut-through pipelining.
    pub segment_bytes: u64,
    /// Overlap source-side production (delta extraction) with transmission.
    pub pipelined: bool,
    /// Sample per-transfer link jitter.
    pub jittered: bool,
}

impl TransferPlan {
    pub fn sparrow_default() -> TransferPlan {
        TransferPlan { streams: 4, segment_bytes: 1 << 20, pipelined: true, jittered: false }
    }

    pub fn single_stream() -> TransferPlan {
        TransferPlan { streams: 1, segment_bytes: 1 << 20, pipelined: true, jittered: false }
    }

    /// Dense full-weight broadcast baseline (PrimeRL-Full): one blocking
    /// stream, no extraction pipeline (weights already materialized).
    pub fn full_weight() -> TransferPlan {
        TransferPlan { streams: 1, segment_bytes: 1 << 22, pipelined: false, jittered: false }
    }

    /// PrimeRL-MultiStream baseline: chunked dense weights over multiple
    /// parallel TCP streams, still blocking.
    pub fn full_weight_multistream(streams: usize) -> TransferPlan {
        TransferPlan { streams, segment_bytes: 1 << 22, pipelined: false, jittered: false }
    }

    fn opts(&self) -> TransferOpts {
        TransferOpts { streams: self.streams, jittered: self.jittered }
    }

    /// Time to deliver `bytes` to one receiver. `produce_bps` is the
    /// source-side production rate (delta extraction encode stream) used
    /// when pipelining; `None` means the payload is already materialized.
    pub fn delivery_time(
        &self,
        link: &Link,
        bytes: u64,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        match (self.pipelined, produce_bps) {
            (true, Some(re)) => {
                link.pipelined_time(bytes, re, self.segment_bytes, self.opts(), rng)
            }
            _ => {
                let extract = produce_bps
                    .map(|re| bytes as f64 * 8.0 / re)
                    .unwrap_or(0.0);
                extract + link.transfer_time(bytes, self.opts(), rng)
            }
        }
    }

    /// Deliver to `n` receivers in one region *without* a relay: every
    /// copy crosses the WAN concurrently (one connection set per actor),
    /// so the region ingress carries O(N) bytes — n*streams TCP flows
    /// sharing the bottleneck (the paper's O(N) cross-region transfers).
    pub fn direct_fanout_time(
        &self,
        wan: &Link,
        bytes: u64,
        n: usize,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        if n == 0 {
            return 0.0;
        }
        if n == 1 {
            return self.delivery_time(wan, bytes, produce_bps, rng);
        }
        let jf = if self.jittered { wan.jitter_factor(rng) } else { 1.0 };
        let per_stream = wan.single_stream_ceiling_bps();
        let aggregate = (per_stream * (n * self.streams) as f64)
            .min(wan.capacity_bps * crate::netsim::link::PROTOCOL_EFFICIENCY)
            * jf;
        let total_bits = n as f64 * bytes as f64 * 8.0;
        let extract = produce_bps
            .map(|re| {
                if self.pipelined {
                    // Cut-through: only the pipeline-fill cost is exposed.
                    (self.segment_bytes.min(bytes) as f64 * 8.0 / re)
                        .max(bytes as f64 * 8.0 / re - total_bits / aggregate)
                } else {
                    bytes as f64 * 8.0 / re
                }
            })
            .unwrap_or(0.0);
        wan.startup_time() + extract + total_bits / aggregate
    }

    /// Relay-based two-tier fanout (§5.2): one WAN copy to the seed actor,
    /// which forwards segments on arrival over the intra-region path.
    /// Cut-through means total ≈ WAN delivery + one segment's intra hop
    /// (when the LAN is faster than the WAN, which it always is here).
    pub fn relay_fanout_time(
        &self,
        wan: &Link,
        intra: &Link,
        bytes: u64,
        n_peers: usize,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        let wan_time = self.delivery_time(wan, bytes, produce_bps, rng);
        if n_peers == 0 {
            return wan_time;
        }
        // The relay re-streams to each peer; intra-region capacity is
        // shared across peers. If intra fanout is slower than WAN arrival,
        // it becomes the bottleneck stage of the pipeline.
        let intra_bw = intra.effective_bps(self.streams);
        let fanout_rate = intra_bw / n_peers as f64;
        let wan_bw = wan.effective_bps(self.streams);
        let seg = self.segment_bytes.min(bytes.max(1)) as f64 * 8.0;
        let tail = if fanout_rate >= wan_bw {
            // LAN drains as fast as the WAN fills: one extra segment hop.
            intra.startup_time() + seg / fanout_rate
        } else {
            // LAN is the bottleneck: residual drain after WAN completes.
            intra.startup_time()
                + bytes as f64 * 8.0 * (1.0 / fanout_rate - 1.0 / wan_bw)
                + seg / wan_bw
        };
        wan_time + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::regions;

    fn rng() -> Rng {
        Rng::new(7)
    }

    #[test]
    fn figure10_progression_naive_varint_multistream() {
        // Fig 10 (US-Canada, Qwen3-8B): naive 414 MB @ 1 stream = 9.22 s,
        // varint 202 MB @ 1 stream = 4.71 s, + multistream = 2.90 s.
        let link = Link::from_profile(&regions::CANADA);
        let mut r = rng();
        let naive = TransferPlan::single_stream().delivery_time(&link, 414_000_000, None, &mut r);
        let varint = TransferPlan::single_stream().delivery_time(&link, 202_000_000, None, &mut r);
        let multi = TransferPlan::sparrow_default().delivery_time(&link, 202_000_000, None, &mut r);
        assert!((7.5..11.5).contains(&naive), "naive {naive:.2} (paper 9.22)");
        assert!((3.8..5.8).contains(&varint), "varint {varint:.2} (paper 4.71)");
        assert!((2.2..3.6).contains(&multi), "multi {multi:.2} (paper 2.90)");
        assert!(naive > varint && varint > multi);
    }

    #[test]
    fn relay_beats_direct_fanout_for_many_peers() {
        let wan = Link::from_profile(&regions::AUSTRALIA);
        let intra = intra_region_link();
        let plan = TransferPlan::sparrow_default();
        let mut r = rng();
        let bytes = 202_000_000;
        let direct = plan.direct_fanout_time(&wan, bytes, 8, Some(3.2e9), &mut r);
        let relay = plan.relay_fanout_time(&wan, &intra, bytes, 7, Some(3.2e9), &mut r);
        assert!(
            relay < direct * 0.75,
            "relay {relay:.2} should be well under direct {direct:.2}"
        );
    }

    #[test]
    fn relay_tail_small_when_lan_fast() {
        let wan = Link::from_profile(&regions::CANADA);
        let intra = intra_region_link();
        let plan = TransferPlan::sparrow_default();
        let mut r = rng();
        let alone = plan.delivery_time(&wan, 202_000_000, None, &mut r);
        let with_peers = plan.relay_fanout_time(&wan, &intra, 202_000_000, 3, None, &mut r);
        assert!(with_peers - alone < 0.5, "tail {:.3} s", with_peers - alone);
    }

    #[test]
    fn pipelining_beats_blocking_extraction() {
        let link = Link::from_profile(&regions::CANADA);
        let mut r = rng();
        let extract_bps = 0.3e9 * 8.0;
        let mut plan = TransferPlan::sparrow_default();
        let piped = plan.delivery_time(&link, 202_000_000, Some(extract_bps), &mut r);
        plan.pipelined = false;
        let blocking = plan.delivery_time(&link, 202_000_000, Some(extract_bps), &mut r);
        assert!(piped < blocking, "{piped:.2} vs {blocking:.2}");
    }

    #[test]
    fn direct_fanout_carries_o_n_bytes() {
        // n receivers cost n copies across the ingress; concurrency lets
        // the flows aggregate past one stream's ceiling but not past the
        // link capacity, so time grows superlinearly vs a single delivery
        // once capacity saturates.
        let wan = Link::from_profile(&regions::CANADA);
        let plan = TransferPlan::full_weight();
        let mut r = rng();
        let t1 = plan.direct_fanout_time(&wan, 1_000_000_000, 1, None, &mut r);
        let t4 = plan.direct_fanout_time(&wan, 1_000_000_000, 4, None, &mut r);
        let t8 = plan.direct_fanout_time(&wan, 1_000_000_000, 8, None, &mut r);
        assert!(t4 > 1.8 * t1, "t1={t1:.1} t4={t4:.1}");
        assert!(t8 > 1.8 * t4 - 1.0, "t4={t4:.1} t8={t8:.1}");
    }
}
