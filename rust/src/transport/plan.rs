//! Transfer timing and bandwidth-aware distribution planning over
//! `netsim` links.
//!
//! Two layers live here:
//!
//! * [`TransferPlan`] — analytic timing of one streaming transfer. The
//!   discrete-event simulator and every transfer-time experiment
//!   (Table 2, Figures 10–13) price transfers through this one model so
//!   that baselines and SparrowRL differ only in the knobs the paper
//!   varies: payload size, stream count, pipelining, and relay fanout.
//! * [`DistributionPlan`] — the geo-distribution tree (§5.2/§7.5): given
//!   a region/link topology, one relay per region receives the delta over
//!   a WAN leg striped to the link's bandwidth-delay product
//!   ([`stripes_for_link`])
//!   and forwards segments cut-through to its regional peers, so each
//!   WAN link carries the payload once instead of once per actor.
//!
//! Building a plan from a WAN preset:
//!
//! ```
//! use sparrowrl::config::wan_preset;
//! use sparrowrl::transport::plan::DistributionPlan;
//!
//! let preset = wan_preset("wan-4").unwrap();
//! let plan = DistributionPlan::from_preset(&preset, 1 << 20);
//! assert_eq!(plan.legs.len(), 4);
//! assert_eq!(plan.n_actors(), 8);
//! // Every WAN leg stripes to at least one stream, and lossy long-RTT
//! // legs (e.g. Japan) stripe wider than short clean ones.
//! assert!(plan.legs.iter().all(|l| l.streams >= 1));
//! // The striped relay tree beats a single-stream direct fan-out.
//! let mut rng = sparrowrl::util::Rng::new(0);
//! let striped = plan.makespan(202_000_000, None, &mut rng);
//! let direct = plan.direct_single_stream_makespan(202_000_000, None, &mut rng);
//! assert!(striped < direct);
//! ```

use crate::config::{RegionProfile, WanPreset};
use crate::netsim::link::PROTOCOL_EFFICIENCY;
use crate::netsim::{Link, TransferOpts};
use crate::transport::stripe::stripes_for_link;
use crate::util::Rng;

/// Default intra-region (same provider/datacenter LAN) path used for
/// relay → peer fanout: 10 Gbps, 1 ms RTT, clean.
pub fn intra_region_link() -> Link {
    Link::emulated(10e9, 0.001, 0.0)
}

/// How a checkpoint (or dense weight blob) is moved.
#[derive(Clone, Copy, Debug)]
pub struct TransferPlan {
    /// Parallel TCP streams (1 = the paper's single-stream baseline).
    pub streams: usize,
    /// Segment size for cut-through pipelining.
    pub segment_bytes: u64,
    /// Overlap source-side production (delta extraction) with transmission.
    pub pipelined: bool,
    /// Sample per-transfer link jitter.
    pub jittered: bool,
}

impl TransferPlan {
    pub fn sparrow_default() -> TransferPlan {
        TransferPlan { streams: 4, segment_bytes: 1 << 20, pipelined: true, jittered: false }
    }

    pub fn single_stream() -> TransferPlan {
        TransferPlan { streams: 1, segment_bytes: 1 << 20, pipelined: true, jittered: false }
    }

    /// Dense full-weight broadcast baseline (PrimeRL-Full): one blocking
    /// stream, no extraction pipeline (weights already materialized).
    pub fn full_weight() -> TransferPlan {
        TransferPlan { streams: 1, segment_bytes: 1 << 22, pipelined: false, jittered: false }
    }

    /// PrimeRL-MultiStream baseline: chunked dense weights over multiple
    /// parallel TCP streams, still blocking.
    pub fn full_weight_multistream(streams: usize) -> TransferPlan {
        TransferPlan { streams, segment_bytes: 1 << 22, pipelined: false, jittered: false }
    }

    fn opts(&self) -> TransferOpts {
        TransferOpts { streams: self.streams, jittered: self.jittered }
    }

    /// Time to deliver `bytes` to one receiver. `produce_bps` is the
    /// source-side production rate (delta extraction encode stream) used
    /// when pipelining; `None` means the payload is already materialized.
    pub fn delivery_time(
        &self,
        link: &Link,
        bytes: u64,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        match (self.pipelined, produce_bps) {
            (true, Some(re)) => {
                link.pipelined_time(bytes, re, self.segment_bytes, self.opts(), rng)
            }
            _ => {
                let extract = produce_bps
                    .map(|re| bytes as f64 * 8.0 / re)
                    .unwrap_or(0.0);
                extract + link.transfer_time(bytes, self.opts(), rng)
            }
        }
    }

    /// Deliver to `n` receivers in one region *without* a relay: every
    /// copy crosses the WAN concurrently (one connection set per actor),
    /// so the region ingress carries O(N) bytes — n*streams TCP flows
    /// sharing the bottleneck (the paper's O(N) cross-region transfers).
    pub fn direct_fanout_time(
        &self,
        wan: &Link,
        bytes: u64,
        n: usize,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        if n == 0 {
            return 0.0;
        }
        if n == 1 {
            return self.delivery_time(wan, bytes, produce_bps, rng);
        }
        let jf = if self.jittered { wan.jitter_factor(rng) } else { 1.0 };
        let per_stream = wan.single_stream_ceiling_bps();
        let aggregate = (per_stream * (n * self.streams) as f64)
            .min(wan.capacity_bps * crate::netsim::link::PROTOCOL_EFFICIENCY)
            * jf;
        let total_bits = n as f64 * bytes as f64 * 8.0;
        let extract = produce_bps
            .map(|re| {
                if self.pipelined {
                    // Cut-through: only the pipeline-fill cost is exposed.
                    (self.segment_bytes.min(bytes) as f64 * 8.0 / re)
                        .max(bytes as f64 * 8.0 / re - total_bits / aggregate)
                } else {
                    bytes as f64 * 8.0 / re
                }
            })
            .unwrap_or(0.0);
        wan.startup_time() + extract + total_bits / aggregate
    }

    /// Relay-based two-tier fanout (§5.2): one WAN copy to the seed actor,
    /// which forwards segments on arrival over the intra-region path.
    /// Cut-through means total ≈ WAN delivery + one segment's intra hop
    /// (when the LAN is faster than the WAN, which it always is here).
    pub fn relay_fanout_time(
        &self,
        wan: &Link,
        intra: &Link,
        bytes: u64,
        n_peers: usize,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        let wan_time = self.delivery_time(wan, bytes, produce_bps, rng);
        if n_peers == 0 {
            return wan_time;
        }
        // The relay re-streams to each peer; intra-region capacity is
        // shared across peers. If intra fanout is slower than WAN arrival,
        // it becomes the bottleneck stage of the pipeline.
        let intra_bw = intra.effective_bps(self.streams);
        let fanout_rate = intra_bw / n_peers as f64;
        let wan_bw = wan.effective_bps(self.streams);
        let seg = self.segment_bytes.min(bytes.max(1)) as f64 * 8.0;
        let tail = if fanout_rate >= wan_bw {
            // LAN drains as fast as the WAN fills: one extra segment hop.
            intra.startup_time() + seg / fanout_rate
        } else {
            // LAN is the bottleneck: residual drain after WAN completes.
            intra.startup_time()
                + bytes as f64 * 8.0 * (1.0 / fanout_rate - 1.0 / wan_bw)
                + seg / wan_bw
        };
        wan_time + tail
    }
}

/// One region of a WAN topology: the hub→region link and how many rollout
/// actors the region hosts.
#[derive(Clone, Debug)]
pub struct RegionTopo {
    pub name: String,
    pub wan: Link,
    pub actors: usize,
}

impl RegionTopo {
    pub fn from_profile(p: &RegionProfile, actors: usize) -> RegionTopo {
        RegionTopo { name: p.name.to_string(), wan: Link::from_profile(p), actors }
    }
}

/// One leg of the distribution tree: hub → regional relay over the WAN
/// (striped), relay → peers over the intra-region LAN (cut-through).
#[derive(Clone, Debug)]
pub struct RelayLeg {
    pub region: String,
    /// Global actor index of the regional relay (the region's first actor,
    /// a dual-role node: rollout actor + forwarding proxy).
    pub relay: usize,
    /// Global actor indices the relay forwards each segment to.
    pub peers: Vec<usize>,
    pub wan: Link,
    pub intra: Link,
    /// WAN stripe count, sized to the leg's bandwidth-delay product.
    pub streams: usize,
}

/// Bandwidth-aware spanning distribution tree over a region topology.
///
/// Global actor indices are assigned in region order (region 0's actors
/// first); each region's first actor is its relay. The hub sends each
/// delta segment once per region — to the relay, over a WAN leg striped
/// to the link's BDP — and the relay forwards it to every regional peer
/// on arrival, so cross-region traffic is O(regions), not O(actors)
/// (the paper's Table 5 relay fanout, generalized to many regions).
#[derive(Clone, Debug)]
pub struct DistributionPlan {
    pub legs: Vec<RelayLeg>,
    /// Segment size used for cut-through pipelining on every leg.
    pub segment_bytes: u64,
}

impl DistributionPlan {
    /// Build the tree from an explicit topology. Regions with zero actors
    /// are skipped (they contribute no leg).
    pub fn build(regions: &[RegionTopo], segment_bytes: u64) -> DistributionPlan {
        let mut legs = Vec::new();
        let mut next = 0usize;
        for r in regions {
            if r.actors == 0 {
                continue;
            }
            let relay = next;
            let peers: Vec<usize> = (next + 1..next + r.actors).collect();
            next += r.actors;
            legs.push(RelayLeg {
                region: r.name.clone(),
                relay,
                peers,
                wan: r.wan.clone(),
                intra: intra_region_link(),
                streams: stripes_for_link(&r.wan),
            });
        }
        DistributionPlan { legs, segment_bytes }
    }

    /// Build from a [`WanPreset`] (`config::wan_preset("wan-4")` etc.).
    pub fn from_preset(preset: &WanPreset, segment_bytes: u64) -> DistributionPlan {
        let topo: Vec<RegionTopo> = preset
            .regions
            .iter()
            .map(|p| RegionTopo::from_profile(p, preset.actors_per_region))
            .collect();
        DistributionPlan::build(&topo, segment_bytes)
    }

    pub fn n_actors(&self) -> usize {
        self.legs.iter().map(|l| 1 + l.peers.len()).sum()
    }

    /// Region index of each global actor, in actor order (runtime wiring).
    pub fn region_map(&self) -> Vec<usize> {
        let mut map = vec![0usize; self.n_actors()];
        for (ri, leg) in self.legs.iter().enumerate() {
            map[leg.relay] = ri;
            for &p in &leg.peers {
                map[p] = ri;
            }
        }
        map
    }

    /// Region index owning global actor `actor`.
    pub fn region_of(&self, actor: usize) -> Option<usize> {
        self.legs
            .iter()
            .position(|l| l.relay == actor || l.peers.contains(&actor))
    }

    /// The per-leg [`TransferPlan`] (striped + pipelined over that leg).
    pub fn leg_transfer_plan(&self, leg: &RelayLeg, pipelined: bool) -> TransferPlan {
        TransferPlan {
            streams: leg.streams,
            segment_bytes: self.segment_bytes,
            pipelined,
            jittered: false,
        }
    }

    /// Delivery makespan of a `payload`-byte delta to *every* actor:
    /// regions run in parallel, each paying one striped WAN copy plus the
    /// relay's cut-through LAN fanout; the slowest region completes last.
    /// `produce_bps` is the source-side streaming-encoder rate (None =
    /// payload already materialized).
    pub fn makespan(&self, payload: u64, produce_bps: Option<f64>, rng: &mut Rng) -> f64 {
        self.legs
            .iter()
            .map(|l| {
                self.leg_transfer_plan(l, produce_bps.is_some()).relay_fanout_time(
                    &l.wan,
                    &l.intra,
                    payload,
                    l.peers.len(),
                    produce_bps,
                    rng,
                )
            })
            .fold(0.0, f64::max)
    }

    /// Baseline makespan: single-stream direct per-actor fan-out (no
    /// relays, no striping) — every copy crosses the WAN, one TCP stream
    /// per actor (the paper's PrimeRL-style O(N) distribution).
    pub fn direct_single_stream_makespan(
        &self,
        payload: u64,
        produce_bps: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        let plan = TransferPlan {
            streams: 1,
            segment_bytes: self.segment_bytes,
            pipelined: produce_bps.is_some(),
            jittered: false,
        };
        self.legs
            .iter()
            .map(|l| {
                plan.direct_fanout_time(&l.wan, payload, 1 + l.peers.len(), produce_bps, rng)
            })
            .fold(0.0, f64::max)
    }

    /// Per-region WAN utilization over a delivery: payload bits that
    /// crossed the region's WAN leg divided by what the leg could carry
    /// in `makespan` seconds at protocol efficiency. Under the relay tree
    /// each leg carries the payload exactly once. Deliberately unclamped:
    /// a value above 1.0 means the makespan claims more than the link can
    /// physically carry — a link-model regression worth surfacing, not
    /// hiding.
    pub fn region_utilization(&self, payload: u64, makespan: f64) -> Vec<(String, f64)> {
        self.legs
            .iter()
            .map(|l| {
                let could = l.wan.capacity_bps * PROTOCOL_EFFICIENCY * makespan.max(1e-9);
                (l.region.clone(), payload as f64 * 8.0 / could)
            })
            .collect()
    }

    /// Per-region WAN ingress bytes for one delta: `payload` once per
    /// region under the relay tree vs once per actor under direct fanout.
    pub fn region_ingress_bytes(&self, payload: u64, direct: bool) -> Vec<(String, u64)> {
        self.legs
            .iter()
            .map(|l| {
                let copies = if direct { 1 + l.peers.len() as u64 } else { 1 };
                (l.region.clone(), payload * copies)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::regions;

    fn rng() -> Rng {
        Rng::new(7)
    }

    #[test]
    fn figure10_progression_naive_varint_multistream() {
        // Fig 10 (US-Canada, Qwen3-8B): naive 414 MB @ 1 stream = 9.22 s,
        // varint 202 MB @ 1 stream = 4.71 s, + multistream = 2.90 s.
        let link = Link::from_profile(&regions::CANADA);
        let mut r = rng();
        let naive = TransferPlan::single_stream().delivery_time(&link, 414_000_000, None, &mut r);
        let varint = TransferPlan::single_stream().delivery_time(&link, 202_000_000, None, &mut r);
        let multi = TransferPlan::sparrow_default().delivery_time(&link, 202_000_000, None, &mut r);
        assert!((7.5..11.5).contains(&naive), "naive {naive:.2} (paper 9.22)");
        assert!((3.8..5.8).contains(&varint), "varint {varint:.2} (paper 4.71)");
        assert!((2.2..3.6).contains(&multi), "multi {multi:.2} (paper 2.90)");
        assert!(naive > varint && varint > multi);
    }

    #[test]
    fn relay_beats_direct_fanout_for_many_peers() {
        let wan = Link::from_profile(&regions::AUSTRALIA);
        let intra = intra_region_link();
        let plan = TransferPlan::sparrow_default();
        let mut r = rng();
        let bytes = 202_000_000;
        let direct = plan.direct_fanout_time(&wan, bytes, 8, Some(3.2e9), &mut r);
        let relay = plan.relay_fanout_time(&wan, &intra, bytes, 7, Some(3.2e9), &mut r);
        assert!(
            relay < direct * 0.75,
            "relay {relay:.2} should be well under direct {direct:.2}"
        );
    }

    #[test]
    fn relay_tail_small_when_lan_fast() {
        let wan = Link::from_profile(&regions::CANADA);
        let intra = intra_region_link();
        let plan = TransferPlan::sparrow_default();
        let mut r = rng();
        let alone = plan.delivery_time(&wan, 202_000_000, None, &mut r);
        let with_peers = plan.relay_fanout_time(&wan, &intra, 202_000_000, 3, None, &mut r);
        assert!(with_peers - alone < 0.5, "tail {:.3} s", with_peers - alone);
    }

    #[test]
    fn pipelining_beats_blocking_extraction() {
        let link = Link::from_profile(&regions::CANADA);
        let mut r = rng();
        let extract_bps = 0.3e9 * 8.0;
        let mut plan = TransferPlan::sparrow_default();
        let piped = plan.delivery_time(&link, 202_000_000, Some(extract_bps), &mut r);
        plan.pipelined = false;
        let blocking = plan.delivery_time(&link, 202_000_000, Some(extract_bps), &mut r);
        assert!(piped < blocking, "{piped:.2} vs {blocking:.2}");
    }

    #[test]
    fn distribution_plan_assigns_contiguous_actors_and_relays() {
        let preset = crate::config::wan_preset("wan-3").unwrap();
        let plan = DistributionPlan::from_preset(&preset, 1 << 20);
        assert_eq!(plan.n_actors(), 6);
        assert_eq!(plan.legs.len(), 3);
        // Relays are each region's first actor; indices are a partition.
        let mut seen = vec![false; plan.n_actors()];
        for (ri, leg) in plan.legs.iter().enumerate() {
            assert!(!seen[leg.relay]);
            seen[leg.relay] = true;
            assert_eq!(plan.region_of(leg.relay), Some(ri));
            for &p in &leg.peers {
                assert!(!seen[p]);
                seen[p] = true;
                assert_eq!(plan.region_of(p), Some(ri));
            }
        }
        assert!(seen.into_iter().all(|x| x));
        let map = plan.region_map();
        assert_eq!(map, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn distribution_plan_skips_empty_regions() {
        let topo = vec![
            RegionTopo::from_profile(&regions::CANADA, 2),
            RegionTopo::from_profile(&regions::JAPAN, 0),
            RegionTopo::from_profile(&regions::ICELAND, 3),
        ];
        let plan = DistributionPlan::build(&topo, 1 << 20);
        assert_eq!(plan.legs.len(), 2);
        assert_eq!(plan.n_actors(), 5);
        assert_eq!(plan.legs[1].region, "iceland");
        assert_eq!(plan.legs[1].relay, 2);
        assert_eq!(plan.legs[1].peers, vec![3, 4]);
    }

    #[test]
    fn striped_relay_tree_beats_single_stream_direct_fanout() {
        // The acceptance invariant behind `exp wan` / BENCH_wan.json: on
        // every 1–4-region preset the striped relay tree strictly beats
        // the single-stream per-actor fan-out baseline.
        for n in 1..=4usize {
            let preset = crate::config::wan_preset(&format!("wan-{n}")).unwrap();
            let plan = DistributionPlan::from_preset(&preset, 1 << 20);
            let mut r = rng();
            let striped = plan.makespan(202_000_000, Some(3.2e9 * 8.0), &mut r);
            let direct =
                plan.direct_single_stream_makespan(202_000_000, Some(3.2e9 * 8.0), &mut r);
            assert!(
                striped < direct,
                "wan-{n}: striped {striped:.2}s must beat direct {direct:.2}s"
            );
        }
    }

    #[test]
    fn wan_legs_stripe_to_their_bdp() {
        let preset = crate::config::wan_preset("wan-4").unwrap();
        let plan = DistributionPlan::from_preset(&preset, 1 << 20);
        for leg in &plan.legs {
            assert_eq!(leg.streams, crate::transport::stripe::stripes_for_link(&leg.wan));
        }
        // Japan's long-RTT lossy path stripes wider than Canada's.
        assert!(plan.legs[1].streams > plan.legs[0].streams);
    }

    #[test]
    fn utilization_and_ingress_account_per_region() {
        let preset = crate::config::wan_preset("wan-2").unwrap();
        let plan = DistributionPlan::from_preset(&preset, 1 << 20);
        let mut r = rng();
        let payload = 100_000_000u64;
        let mk = plan.makespan(payload, None, &mut r);
        for (region, util) in plan.region_utilization(payload, mk) {
            assert!(util > 0.0 && util <= 1.0, "{region}: {util}");
        }
        let relay_in = plan.region_ingress_bytes(payload, false);
        let direct_in = plan.region_ingress_bytes(payload, true);
        for ((_, a), (_, b)) in relay_in.iter().zip(&direct_in) {
            assert_eq!(*a, payload);
            assert_eq!(*b, 2 * payload, "2 actors per region -> 2 WAN copies");
        }
    }

    #[test]
    fn direct_fanout_carries_o_n_bytes() {
        // n receivers cost n copies across the ingress; concurrency lets
        // the flows aggregate past one stream's ceiling but not past the
        // link capacity, so time grows superlinearly vs a single delivery
        // once capacity saturates.
        let wan = Link::from_profile(&regions::CANADA);
        let plan = TransferPlan::full_weight();
        let mut r = rng();
        let t1 = plan.direct_fanout_time(&wan, 1_000_000_000, 1, None, &mut r);
        let t4 = plan.direct_fanout_time(&wan, 1_000_000_000, 4, None, &mut r);
        let t8 = plan.direct_fanout_time(&wan, 1_000_000_000, 8, None, &mut r);
        assert!(t4 > 1.8 * t1, "t1={t1:.1} t4={t4:.1}");
        assert!(t8 > 1.8 * t4 - 1.0, "t4={t4:.1} t8={t8:.1}");
    }
}
