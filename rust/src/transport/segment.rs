//! Segment framing: the unit of cut-through forwarding (§5.2, Fig 7).
//!
//! Wire layout (little-endian):
//! ```text
//! magic "SSEG" | version u64 | seq u32 | total u32 | len u32 |
//! payload [len] | checksum u64 (FNV-1a over header+payload)
//! ```
//! The per-segment checksum catches transport corruption early; end-to-end
//! integrity is still the checkpoint's SHA-256 verified after reassembly.

pub const SEG_MAGIC: [u8; 4] = *b"SSEG";
pub const DEFAULT_SEGMENT_BYTES: usize = 1 << 20; // 1 MiB

/// `total` value meaning "stream length not yet known". A single-pass
/// streaming encoder (`delta/stream.rs`) only learns the segment count at
/// the end of the scan, so every frame except the last carries this
/// sentinel and the final frame binds the true geometry. Legacy
/// `split_into_segments` streams carry the real total on every frame;
/// receivers (`Reassembler`, `DeltaStreamDecoder`) accept both. The
/// sentinel is unambiguous because a materialized stream always has
/// `total >= 1`.
pub const TOTAL_UNKNOWN: u32 = 0;
const HEADER_LEN: usize = 4 + 8 + 4 + 4 + 4;

/// One transfer segment of a delta checkpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Checkpoint version this segment belongs to.
    pub version: u64,
    /// Position in the checkpoint byte stream.
    pub seq: u32,
    /// Total number of segments in the checkpoint, or [`TOTAL_UNKNOWN`]
    /// on the non-final frames of a streaming encode.
    pub total: u32,
    pub payload: Vec<u8>,
}

impl Segment {
    /// Serialize to the framed wire format.
    pub fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.payload.len() + 8);
        out.extend_from_slice(&SEG_MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.total.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let ck = fnv1a(&out);
        out.extend_from_slice(&ck.to_le_bytes());
        out
    }

    /// Parse one framed segment from the front of `buf`; returns the
    /// segment and bytes consumed. `None` if incomplete or corrupt.
    pub fn from_wire(buf: &[u8]) -> Option<(Segment, usize)> {
        if buf.len() < HEADER_LEN {
            return None;
        }
        if buf[0..4] != SEG_MAGIC {
            return None;
        }
        let version = u64::from_le_bytes(buf[4..12].try_into().unwrap());
        let seq = u32::from_le_bytes(buf[12..16].try_into().unwrap());
        let total = u32::from_le_bytes(buf[16..20].try_into().unwrap());
        let len = u32::from_le_bytes(buf[20..24].try_into().unwrap()) as usize;
        let end = HEADER_LEN.checked_add(len)?;
        if buf.len() < end + 8 {
            return None;
        }
        let expect = u64::from_le_bytes(buf[end..end + 8].try_into().unwrap());
        if fnv1a(&buf[..end]) != expect {
            return None;
        }
        let payload = buf[HEADER_LEN..end].to_vec();
        Some((Segment { version, seq, total, payload }, end + 8))
    }

    /// Wire size of this segment.
    pub fn wire_len(&self) -> usize {
        HEADER_LEN + self.payload.len() + 8
    }
}

/// Word-wise 64-bit checksum (FNV-1a style folding over u64 lanes).
/// Byte-serial FNV capped framing at ~0.6 GB/s; folding 8 bytes per
/// round is ~8x faster at equivalent error-detection strength for
/// transport corruption (see EXPERIMENTS.md §Perf).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325 ^ (bytes.len() as u64).wrapping_mul(PRIME);
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        h = (h ^ w).wrapping_mul(PRIME);
        h ^= h >> 29;
    }
    let mut tail: u64 = 0;
    for (i, &b) in chunks.remainder().iter().enumerate() {
        tail |= (b as u64) << (8 * i);
    }
    h = (h ^ tail).wrapping_mul(PRIME);
    h ^= h >> 32;
    h
}

/// Packetize a checkpoint byte stream into segments of at most
/// `segment_bytes` (§5.2: "packetizes it into a sequence of segments that
/// can be transmitted and buffered independently").
pub fn split_into_segments(version: u64, bytes: &[u8], segment_bytes: usize) -> Vec<Segment> {
    assert!(segment_bytes > 0);
    if bytes.is_empty() {
        return vec![Segment { version, seq: 0, total: 1, payload: Vec::new() }];
    }
    let total = bytes.len().div_ceil(segment_bytes) as u32;
    bytes
        .chunks(segment_bytes)
        .enumerate()
        .map(|(i, c)| Segment { version, seq: i as u32, total, payload: c.to_vec() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn wire_round_trip() {
        let s = Segment { version: 7, seq: 3, total: 9, payload: vec![1, 2, 3, 4, 5] };
        let wire = s.to_wire();
        assert_eq!(wire.len(), s.wire_len());
        let (back, used) = Segment::from_wire(&wire).unwrap();
        assert_eq!(back, s);
        assert_eq!(used, wire.len());
    }

    #[test]
    fn corruption_rejected() {
        let s = Segment { version: 1, seq: 0, total: 1, payload: vec![9; 100] };
        let mut wire = s.to_wire();
        for i in [0usize, 5, 30, wire.len() - 1] {
            wire[i] ^= 0x40;
            assert!(Segment::from_wire(&wire).is_none(), "flip at {i}");
            wire[i] ^= 0x40;
        }
        assert!(Segment::from_wire(&wire).is_some());
    }

    #[test]
    fn incomplete_buffer_returns_none() {
        let s = Segment { version: 1, seq: 0, total: 1, payload: vec![7; 50] };
        let wire = s.to_wire();
        for cut in 0..wire.len() {
            assert!(Segment::from_wire(&wire[..cut]).is_none());
        }
    }

    #[test]
    fn split_covers_all_bytes_in_order() {
        let bytes: Vec<u8> = (0..2500u32).map(|x| x as u8).collect();
        let segs = split_into_segments(4, &bytes, 1000);
        assert_eq!(segs.len(), 3);
        assert!(segs.iter().all(|s| s.total == 3 && s.version == 4));
        let glued: Vec<u8> = segs.iter().flat_map(|s| s.payload.clone()).collect();
        assert_eq!(glued, bytes);
        assert_eq!(segs[2].payload.len(), 500);
    }

    #[test]
    fn empty_stream_gets_single_empty_segment() {
        let segs = split_into_segments(1, &[], 1024);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].total, 1);
        assert!(segs[0].payload.is_empty());
    }

    #[test]
    fn prop_framing_survives_concatenation() {
        prop::check("segments parse back from a concatenated stream", 30, |rng| {
            let n = rng.range(1, 20);
            let segs: Vec<Segment> = (0..n)
                .map(|i| Segment {
                    version: rng.next_u64(),
                    seq: i as u32,
                    total: n as u32,
                    payload: (0..rng.range(0, 300)).map(|_| rng.next_u64() as u8).collect(),
                })
                .collect();
            let mut stream = Vec::new();
            for s in &segs {
                stream.extend_from_slice(&s.to_wire());
            }
            let mut pos = 0;
            let mut parsed = Vec::new();
            while pos < stream.len() {
                let (s, used) = Segment::from_wire(&stream[pos..]).expect("parse");
                parsed.push(s);
                pos += used;
            }
            assert_eq!(parsed, segs);
        });
    }
}
