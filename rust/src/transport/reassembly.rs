//! Deterministic reassembly of a segmented delta checkpoint (§5.2).
//!
//! Tolerates arbitrary arrival order and duplicates (relay retries);
//! rejects cross-version mixing and inconsistent segment geometry. On
//! completion the caller gets the raw byte stream; committing it as a
//! `DeltaCheckpoint` re-verifies the embedded SHA-256 (the paper's
//! "integrity verified against the delta checkpoint hash").

use super::segment::Segment;
use crate::delta::DeltaCheckpoint;

/// Upper bound on the segment count a reassembler will allocate for,
/// whether from a claimed total or from streaming growth — one corrupt or
/// hostile seq/total must not trigger a multi-gigabyte allocation before
/// the integrity hash can reject the stream. 2^20 segments is 1 TiB at
/// the default 1 MiB segment size.
pub const MAX_SEGMENTS: u32 = 1 << 20;

/// Incremental reassembly buffer for one checkpoint version.
pub struct Reassembler {
    version: u64,
    total: Option<u32>,
    parts: Vec<Option<Vec<u8>>>,
    received: usize,
    bytes: usize,
    duplicates: u64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AcceptError {
    WrongVersion { expected: u64, got: u64 },
    GeometryMismatch,
    SeqOutOfRange,
}

impl Reassembler {
    pub fn new(version: u64) -> Reassembler {
        Reassembler {
            version,
            total: None,
            parts: Vec::new(),
            received: 0,
            bytes: 0,
            duplicates: 0,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Fraction of segments received (staging progress metric).
    pub fn progress(&self) -> f64 {
        match self.total {
            Some(t) if t > 0 => self.received as f64 / t as f64,
            _ => 0.0,
        }
    }

    pub fn bytes_staged(&self) -> usize {
        self.bytes
    }

    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    /// Accept one segment. Duplicate segments are counted and ignored.
    ///
    /// Segments carrying [`TOTAL_UNKNOWN`](crate::transport::TOTAL_UNKNOWN)
    /// (the non-final frames of a streaming encode) grow the buffer as
    /// needed; the geometry binds when a frame with a real total arrives.
    pub fn accept(&mut self, seg: Segment) -> Result<(), AcceptError> {
        if seg.version != self.version {
            return Err(AcceptError::WrongVersion { expected: self.version, got: seg.version });
        }
        if seg.total != super::segment::TOTAL_UNKNOWN {
            if seg.total > MAX_SEGMENTS {
                return Err(AcceptError::GeometryMismatch);
            }
            match self.total {
                None => {
                    if (seg.total as usize) < self.parts.len() {
                        // We already saw a seq beyond this claimed total.
                        return Err(AcceptError::GeometryMismatch);
                    }
                    self.total = Some(seg.total);
                    self.parts.resize_with(seg.total as usize, || None);
                }
                Some(t) if t != seg.total => return Err(AcceptError::GeometryMismatch),
                _ => {}
            }
        }
        let i = seg.seq as usize;
        if i >= self.parts.len() {
            if self.total.is_some() || seg.seq >= MAX_SEGMENTS {
                return Err(AcceptError::SeqOutOfRange);
            }
            // Streaming frames before the geometry is known: grow.
            self.parts.resize_with(i + 1, || None);
        }
        match &self.parts[i] {
            Some(existing) => {
                // Duplicate: must be byte-identical, else geometry lied.
                if *existing != seg.payload {
                    return Err(AcceptError::GeometryMismatch);
                }
                self.duplicates += 1;
            }
            None => {
                self.bytes += seg.payload.len();
                self.parts[i] = Some(seg.payload);
                self.received += 1;
            }
        }
        Ok(())
    }

    /// Read-only classification of `seg`: `Ok(true)` if [`accept`](Self::accept)
    /// would count it as a duplicate, `Ok(false)` if it would stage new
    /// content, and the same error `accept` would return otherwise. Lets a
    /// relay forward the borrowed segment *before* moving it into `accept`,
    /// so cut-through fanout never copies the payload.
    pub fn precheck(&self, seg: &Segment) -> Result<bool, AcceptError> {
        if seg.version != self.version {
            return Err(AcceptError::WrongVersion { expected: self.version, got: seg.version });
        }
        let mut bound = self.total;
        if seg.total != super::segment::TOTAL_UNKNOWN {
            if seg.total > MAX_SEGMENTS {
                return Err(AcceptError::GeometryMismatch);
            }
            match self.total {
                None => {
                    if (seg.total as usize) < self.parts.len() {
                        return Err(AcceptError::GeometryMismatch);
                    }
                    bound = Some(seg.total);
                }
                Some(t) if t != seg.total => return Err(AcceptError::GeometryMismatch),
                _ => {}
            }
        }
        let i = seg.seq as usize;
        let len = bound.map(|t| t as usize).unwrap_or(self.parts.len()).max(self.parts.len());
        if i >= len && (bound.is_some() || seg.seq >= MAX_SEGMENTS) {
            return Err(AcceptError::SeqOutOfRange);
        }
        match self.parts.get(i).and_then(|p| p.as_ref()) {
            Some(existing) if *existing != seg.payload => Err(AcceptError::GeometryMismatch),
            Some(_) => Ok(true),
            None => Ok(false),
        }
    }

    pub fn is_complete(&self) -> bool {
        self.total.map(|t| self.received == t as usize).unwrap_or(false)
    }

    /// Concatenate into the checkpoint byte stream (None until complete).
    pub fn assemble(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let mut out = Vec::with_capacity(self.bytes);
        for p in &self.parts {
            out.extend_from_slice(p.as_ref().unwrap());
        }
        Some(out)
    }

    /// Assemble and hash-verify into a checkpoint artifact.
    pub fn into_checkpoint(self) -> Option<Result<DeltaCheckpoint, crate::delta::DecodeError>> {
        self.assemble().map(DeltaCheckpoint::from_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, ApplyMode, ModelLayout, ParamSet};
    use crate::transport::segment::split_into_segments;
    use crate::util::{prop, Rng};

    fn checkpoint(seed: u64) -> DeltaCheckpoint {
        let l = ModelLayout::transformer("t", 128, 32, 2, 64);
        let mut rng = Rng::new(seed);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        for t in &mut new.tensors {
            for _ in 0..8 {
                let i = rng.range(0, t.len());
                t[i] = crate::util::Bf16::from_bits(t[i].to_bits() ^ 0x0020);
            }
        }
        DeltaCheckpoint::seal(&extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign))
    }

    #[test]
    fn in_order_reassembly() {
        let c = checkpoint(1);
        let segs = split_into_segments(c.version, &c.bytes, 64);
        let mut r = Reassembler::new(c.version);
        for s in segs {
            r.accept(s).unwrap();
        }
        assert!(r.is_complete());
        let back = r.into_checkpoint().unwrap().unwrap();
        assert_eq!(back.bytes, c.bytes);
        assert_eq!(back.hash, c.hash);
    }

    #[test]
    fn out_of_order_and_duplicates_tolerated() {
        let c = checkpoint(2);
        let mut segs = split_into_segments(c.version, &c.bytes, 50);
        let mut rng = Rng::new(3);
        rng.shuffle(&mut segs);
        // Duplicate a third of them.
        let dups: Vec<_> = segs.iter().step_by(3).cloned().collect();
        let mut r = Reassembler::new(c.version);
        for s in segs.into_iter().chain(dups) {
            r.accept(s).unwrap();
        }
        assert!(r.is_complete());
        assert!(r.duplicates() > 0);
        assert_eq!(r.assemble().unwrap(), c.bytes);
    }

    #[test]
    fn cross_version_mixing_rejected() {
        let c = checkpoint(4);
        let segs = split_into_segments(c.version, &c.bytes, 64);
        let mut r = Reassembler::new(99);
        assert_eq!(
            r.accept(segs[0].clone()),
            Err(AcceptError::WrongVersion { expected: 99, got: c.version })
        );
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let c = checkpoint(5);
        let a = split_into_segments(c.version, &c.bytes, 64);
        let b = split_into_segments(c.version, &c.bytes, 128);
        let mut r = Reassembler::new(c.version);
        r.accept(a[0].clone()).unwrap();
        assert_eq!(r.accept(b[0].clone()), Err(AcceptError::GeometryMismatch));
    }

    #[test]
    fn precheck_agrees_with_accept() {
        // Property: for a stream with shuffles, duplicates, a wrong-version
        // frame, and a geometry lie, precheck's verdict always matches what
        // accept then does — including after state evolves.
        prop::check("precheck mirrors accept", 20, |rng| {
            let c = checkpoint(rng.range(10, 500) as u64);
            let mut segs = split_into_segments(c.version, &c.bytes, 64);
            let dups: Vec<_> = segs.iter().step_by(2).cloned().collect();
            segs.extend(dups);
            segs.push(Segment { version: c.version + 7, seq: 0, total: 1, payload: vec![0] });
            let mut lie = segs[0].clone();
            lie.payload.push(0xFF);
            segs.push(lie);
            rng.shuffle(&mut segs);
            let mut r = Reassembler::new(c.version);
            for s in segs {
                let verdict = r.precheck(&s);
                let before = r.duplicates();
                match r.accept(s) {
                    Ok(()) => {
                        let was_dup = r.duplicates() > before;
                        assert_eq!(verdict, Ok(was_dup));
                    }
                    Err(e) => assert_eq!(verdict, Err(e)),
                }
            }
            assert!(r.is_complete());
        });
    }

    #[test]
    fn incomplete_does_not_assemble() {
        let c = checkpoint(6);
        let segs = split_into_segments(c.version, &c.bytes, 64);
        let mut r = Reassembler::new(c.version);
        for s in segs.iter().take(segs.len() - 1) {
            r.accept(s.clone()).unwrap();
        }
        assert!(!r.is_complete());
        assert!(r.assemble().is_none());
        assert!(r.progress() < 1.0);
    }

    #[test]
    fn streaming_unknown_totals_reassemble() {
        use crate::transport::segment::TOTAL_UNKNOWN;
        let c = checkpoint(7);
        let mut segs = split_into_segments(c.version, &c.bytes, 50);
        assert!(segs.len() > 2);
        // Rewrite as a streaming encode would emit: only the final frame
        // carries the geometry.
        let n = segs.len() as u32;
        for s in segs.iter_mut() {
            s.total = if s.seq == n - 1 { n } else { TOTAL_UNKNOWN };
        }
        // Out of order: the buffer must grow before the geometry is known.
        let mut rng = Rng::new(9);
        rng.shuffle(&mut segs);
        let mut r = Reassembler::new(c.version);
        for s in segs {
            r.accept(s).unwrap();
        }
        assert!(r.is_complete());
        assert_eq!(r.assemble().unwrap(), c.bytes);
    }

    #[test]
    fn hostile_seq_and_total_bounded_by_cap() {
        use crate::transport::segment::TOTAL_UNKNOWN;
        let mut r = Reassembler::new(1);
        // A corrupt streaming frame with an absurd seq must not allocate.
        assert_eq!(
            r.accept(Segment { version: 1, seq: u32::MAX, total: TOTAL_UNKNOWN, payload: vec![1] }),
            Err(AcceptError::SeqOutOfRange)
        );
        // A claimed total past the cap is rejected before allocation too.
        assert_eq!(
            r.accept(Segment { version: 1, seq: 0, total: u32::MAX, payload: vec![1] }),
            Err(AcceptError::GeometryMismatch)
        );
        assert_eq!(r.bytes_staged(), 0);
    }

    #[test]
    fn streaming_total_below_seen_seq_is_geometry_error() {
        use crate::transport::segment::TOTAL_UNKNOWN;
        let mut r = Reassembler::new(1);
        r.accept(Segment { version: 1, seq: 5, total: TOTAL_UNKNOWN, payload: vec![1] })
            .unwrap();
        // A final frame claiming only 3 segments contradicts seq 5.
        assert_eq!(
            r.accept(Segment { version: 1, seq: 2, total: 3, payload: vec![2] }),
            Err(AcceptError::GeometryMismatch)
        );
    }

    #[test]
    fn prop_any_permutation_reassembles_identically() {
        prop::check("reassembly is permutation invariant", 30, |rng| {
            let c = checkpoint(rng.next_u64());
            let seg_size = rng.range(16, 200);
            let mut segs = split_into_segments(c.version, &c.bytes, seg_size);
            rng.shuffle(&mut segs);
            let mut r = Reassembler::new(c.version);
            for s in segs {
                r.accept(s).unwrap();
            }
            let back = r.into_checkpoint().unwrap().unwrap();
            assert_eq!(back.bytes, c.bytes);
        });
    }
}
