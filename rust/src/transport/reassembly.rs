//! Deterministic reassembly of a segmented delta checkpoint (§5.2).
//!
//! Tolerates arbitrary arrival order and duplicates (relay retries);
//! rejects cross-version mixing and inconsistent segment geometry. On
//! completion the caller gets the raw byte stream; committing it as a
//! `DeltaCheckpoint` re-verifies the embedded SHA-256 (the paper's
//! "integrity verified against the delta checkpoint hash").

use super::segment::Segment;
use crate::delta::DeltaCheckpoint;

/// Incremental reassembly buffer for one checkpoint version.
pub struct Reassembler {
    version: u64,
    total: Option<u32>,
    parts: Vec<Option<Vec<u8>>>,
    received: usize,
    bytes: usize,
    duplicates: u64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AcceptError {
    WrongVersion { expected: u64, got: u64 },
    GeometryMismatch,
    SeqOutOfRange,
}

impl Reassembler {
    pub fn new(version: u64) -> Reassembler {
        Reassembler {
            version,
            total: None,
            parts: Vec::new(),
            received: 0,
            bytes: 0,
            duplicates: 0,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Fraction of segments received (staging progress metric).
    pub fn progress(&self) -> f64 {
        match self.total {
            Some(t) if t > 0 => self.received as f64 / t as f64,
            _ => 0.0,
        }
    }

    pub fn bytes_staged(&self) -> usize {
        self.bytes
    }

    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    /// Accept one segment. Duplicate segments are counted and ignored.
    pub fn accept(&mut self, seg: Segment) -> Result<(), AcceptError> {
        if seg.version != self.version {
            return Err(AcceptError::WrongVersion { expected: self.version, got: seg.version });
        }
        match self.total {
            None => {
                self.total = Some(seg.total);
                self.parts = vec![None; seg.total as usize];
            }
            Some(t) if t != seg.total => return Err(AcceptError::GeometryMismatch),
            _ => {}
        }
        let i = seg.seq as usize;
        if i >= self.parts.len() {
            return Err(AcceptError::SeqOutOfRange);
        }
        match &self.parts[i] {
            Some(existing) => {
                // Duplicate: must be byte-identical, else geometry lied.
                if *existing != seg.payload {
                    return Err(AcceptError::GeometryMismatch);
                }
                self.duplicates += 1;
            }
            None => {
                self.bytes += seg.payload.len();
                self.parts[i] = Some(seg.payload);
                self.received += 1;
            }
        }
        Ok(())
    }

    pub fn is_complete(&self) -> bool {
        self.total.map(|t| self.received == t as usize).unwrap_or(false)
    }

    /// Concatenate into the checkpoint byte stream (None until complete).
    pub fn assemble(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let mut out = Vec::with_capacity(self.bytes);
        for p in &self.parts {
            out.extend_from_slice(p.as_ref().unwrap());
        }
        Some(out)
    }

    /// Assemble and hash-verify into a checkpoint artifact.
    pub fn into_checkpoint(self) -> Option<Result<DeltaCheckpoint, crate::delta::DecodeError>> {
        self.assemble().map(DeltaCheckpoint::from_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{extract_delta, ApplyMode, ModelLayout, ParamSet};
    use crate::transport::segment::split_into_segments;
    use crate::util::{prop, Rng};

    fn checkpoint(seed: u64) -> DeltaCheckpoint {
        let l = ModelLayout::transformer("t", 128, 32, 2, 64);
        let mut rng = Rng::new(seed);
        let old = ParamSet::random(&l, 0.02, &mut rng);
        let mut new = old.clone();
        for t in &mut new.tensors {
            for _ in 0..8 {
                let i = rng.range(0, t.len());
                t[i] = crate::util::Bf16::from_bits(t[i].to_bits() ^ 0x0020);
            }
        }
        DeltaCheckpoint::seal(&extract_delta(&l, &old, &new, 0, 1, ApplyMode::Assign))
    }

    #[test]
    fn in_order_reassembly() {
        let c = checkpoint(1);
        let segs = split_into_segments(c.version, &c.bytes, 64);
        let mut r = Reassembler::new(c.version);
        for s in segs {
            r.accept(s).unwrap();
        }
        assert!(r.is_complete());
        let back = r.into_checkpoint().unwrap().unwrap();
        assert_eq!(back.bytes, c.bytes);
        assert_eq!(back.hash, c.hash);
    }

    #[test]
    fn out_of_order_and_duplicates_tolerated() {
        let c = checkpoint(2);
        let mut segs = split_into_segments(c.version, &c.bytes, 50);
        let mut rng = Rng::new(3);
        rng.shuffle(&mut segs);
        // Duplicate a third of them.
        let dups: Vec<_> = segs.iter().step_by(3).cloned().collect();
        let mut r = Reassembler::new(c.version);
        for s in segs.into_iter().chain(dups) {
            r.accept(s).unwrap();
        }
        assert!(r.is_complete());
        assert!(r.duplicates() > 0);
        assert_eq!(r.assemble().unwrap(), c.bytes);
    }

    #[test]
    fn cross_version_mixing_rejected() {
        let c = checkpoint(4);
        let segs = split_into_segments(c.version, &c.bytes, 64);
        let mut r = Reassembler::new(99);
        assert_eq!(
            r.accept(segs[0].clone()),
            Err(AcceptError::WrongVersion { expected: 99, got: c.version })
        );
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let c = checkpoint(5);
        let a = split_into_segments(c.version, &c.bytes, 64);
        let b = split_into_segments(c.version, &c.bytes, 128);
        let mut r = Reassembler::new(c.version);
        r.accept(a[0].clone()).unwrap();
        assert_eq!(r.accept(b[0].clone()), Err(AcceptError::GeometryMismatch));
    }

    #[test]
    fn incomplete_does_not_assemble() {
        let c = checkpoint(6);
        let segs = split_into_segments(c.version, &c.bytes, 64);
        let mut r = Reassembler::new(c.version);
        for s in segs.iter().take(segs.len() - 1) {
            r.accept(s.clone()).unwrap();
        }
        assert!(!r.is_complete());
        assert!(r.assemble().is_none());
        assert!(r.progress() < 1.0);
    }

    #[test]
    fn prop_any_permutation_reassembles_identically() {
        prop::check("reassembly is permutation invariant", 30, |rng| {
            let c = checkpoint(rng.next_u64());
            let seg_size = rng.range(16, 200);
            let mut segs = split_into_segments(c.version, &c.bytes, seg_size);
            rng.shuffle(&mut segs);
            let mut r = Reassembler::new(c.version);
            for s in segs {
                r.accept(s).unwrap();
            }
            let back = r.into_checkpoint().unwrap().unwrap();
            assert_eq!(back.bytes, c.bytes);
        });
    }
}
