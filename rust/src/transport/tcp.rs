//! The Tcp backend: the same executor and actor worker as InProc, but
//! every message crosses a real loopback socket through `rt::net`'s
//! framing — length-prefixed [`Msg`] frames, per-stream [`Throttle`]d
//! writers emulating WAN bandwidth, and multi-stream segment push
//! (stripe `seq % streams`, like the paper's parallel TCP streams).
//!
//! Topology per actor: `streams` sockets, connected in stripe order.
//! Stripe 0 is the duplex control stream (jobs, commits, results, acks,
//! membership); stripes 1.. carry only hub→actor segment pushes. The
//! actor side runs one OS thread per actor (a process stand-in: it
//! shares no memory with the hub — all state flows through sockets) plus
//! one reader thread per socket feeding the worker's mailbox, so
//! segments stage mid-generation exactly as in-process.
//!
//! Failure semantics are real: a crashed actor's sockets reset, the
//! hub's reader surfaces [`Event::Down`], and the executor's lease
//! machinery requeues its prompts — no global restart. A *partitioned*
//! actor (sockets up, silent) is caught by lease expiry while it owes
//! leased work, and by the hub's commit-ack timeout once it owes only an
//! ack. Both are injectable via [`KillSpec`] for the fault-tolerance
//! suite.

use crate::rt::net::{read_msg, write_msg, Msg, Throttle};
use crate::transport::api::{ActorEndpoint, ActorRunner, Closed, Event, HubEndpoint, Polled, Transport};
use crate::transport::stripe::stream_for;
use crate::transport::Segment;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::Scope;
use std::time::{Duration, Instant};

/// How an injected failure manifests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillMode {
    /// Slam every socket shut and exit the actor thread: the hub sees
    /// EOF/reset immediately (crash, preemption, OOM-kill).
    Crash,
    /// Keep sockets open but stop replying or applying anything: only
    /// lease expiry can detect it (network partition, GPU hang).
    Stall,
    /// Spot preemption with notice: the actor sends a `Msg::Draining`
    /// warning on its control stream the moment the trigger job arrives,
    /// keeps working through the warning window, then all sockets slam
    /// shut `warn_ms` later. Because warning and EOF share the FIFO
    /// control stream, the hub always observes the warning first — a
    /// generous window lets the drain complete gracefully; `warn_ms: 0`
    /// kills before the actor even sees the trigger job, so its leases
    /// take the ordinary reissue path.
    Preempt { warn_ms: u64 },
}

/// Fault injection: kill `actor` when it receives a job for
/// `at_version` (i.e. mid-step, after dispatch, before results).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub actor: u32,
    pub at_version: u64,
    pub mode: KillMode,
}

/// Tcp backend configuration (carried in `LocalRunConfig`).
#[derive(Clone, Debug)]
pub struct TcpConfig {
    /// Sockets per actor; segments stripe across all of them.
    pub streams: usize,
    /// Aggregate hub→actor segment bandwidth emulation (token-bucket per
    /// stream at `bits_per_s / streams`), `None` = unthrottled loopback.
    pub bits_per_s: Option<f64>,
    /// Injected failures, at most one per actor (fault scripts mixing
    /// crash, stall, and preemption across the fleet).
    pub kills: Vec<KillSpec>,
}

impl Default for TcpConfig {
    fn default() -> TcpConfig {
        TcpConfig { streams: 1, bits_per_s: None, kills: Vec::new() }
    }
}

/// The loopback-socket [`Transport`].
pub struct TcpTransport {
    pub cfg: TcpConfig,
}

impl TcpTransport {
    pub fn new(cfg: TcpConfig) -> TcpTransport {
        TcpTransport { cfg }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn launch<'scope, 'env>(
        &'env self,
        scope: &'scope Scope<'scope, 'env>,
        n: usize,
        runner: ActorRunner<'env>,
    ) -> Result<Box<dyn HubEndpoint + 'env>> {
        let streams = self.cfg.streams.max(1);
        let listener = TcpListener::bind("127.0.0.1:0").context("bind loopback listener")?;
        let addr = listener.local_addr()?;
        let (ev_tx, ev_rx) = channel::<Event>();

        // Actor side: one thread per actor, connecting back to the hub.
        for i in 0..n {
            let actor = i as u32;
            let kill = self.cfg.kills.iter().find(|k| k.actor == actor).copied();
            scope.spawn(move || actor_shell(addr, actor, streams, kill, runner));
        }

        // Hub side: accept and handshake n * streams sockets. Each socket
        // opens with a raw `Hello` naming its actor; stripe index is the
        // actor's connect order (shells connect stripes sequentially).
        // On failure, every accepted socket is shut down so already-
        // connected shells exit instead of hanging the scope join.
        let mut writers: Vec<Vec<TcpStream>> = (0..n).map(|_| Vec::new()).collect();
        if let Err(e) = accept_all(&listener, &mut writers, n, streams, &ev_tx) {
            for s in writers.iter().flatten() {
                let _ = s.shutdown(Shutdown::Both);
            }
            return Err(e);
        }
        let throttles: Vec<Vec<Option<Throttle>>> = (0..n)
            .map(|_| {
                (0..streams)
                    .map(|_| self.cfg.bits_per_s.map(|b| Throttle::new(b / streams as f64)))
                    .collect()
            })
            .collect();
        Ok(Box::new(TcpHub {
            active: vec![true; n],
            writers: writers.into_iter().map(Some).collect(),
            throttles,
            events: ev_rx,
            pending: VecDeque::new(),
            streams,
        }))
    }
}

/// Accept + handshake every expected socket into `writers[actor][stripe]`,
/// spawning the stripe-0 reader per actor. Partial progress stays in
/// `writers` so the caller can clean up on error.
fn accept_all(
    listener: &TcpListener,
    writers: &mut [Vec<TcpStream>],
    n: usize,
    streams: usize,
    ev_tx: &Sender<Event>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut accepted = 0;
    while accepted < n * streams {
        match listener.accept() {
            Ok((mut sock, _)) => {
                sock.set_nonblocking(false)?;
                sock.set_nodelay(true)?;
                sock.set_read_timeout(Some(Duration::from_secs(10)))?;
                let hello = read_msg(&mut sock).context("handshake")?;
                let Msg::Hello { actor, .. } = hello else {
                    bail!("expected handshake Hello, got {hello:?}");
                };
                let a = actor as usize;
                anyhow::ensure!(a < n, "handshake from unknown actor {actor}");
                let stripe = writers[a].len();
                anyhow::ensure!(stripe < streams, "actor {actor}: too many sockets");
                sock.set_read_timeout(None)?;
                if stripe == 0 {
                    // Stripe 0 is duplex: its read half feeds the hub's
                    // event stream.
                    let rd = sock.try_clone()?;
                    let tx = ev_tx.clone();
                    std::thread::spawn(move || hub_reader(rd, actor, tx));
                }
                writers[a].push(sock);
                accepted += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for actor connections ({accepted}/{})",
                    n * streams
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Hub-side reader for one actor's control stream: frames become
/// [`Event::Msg`]; EOF/reset becomes [`Event::Down`].
fn hub_reader(mut sock: TcpStream, actor: u32, tx: Sender<Event>) {
    loop {
        match read_msg(&mut sock) {
            Ok(msg) => {
                let done = matches!(msg, Msg::Bye);
                if tx.send(Event::Msg { actor, msg }).is_err() || done {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Event::Down { actor, reason: format!("actor {actor} link: {e:#}") });
                return;
            }
        }
    }
}

struct TcpHub {
    /// `[actor] -> [stripe]` write halves; `None` once the actor is cut.
    writers: Vec<Option<Vec<TcpStream>>>,
    throttles: Vec<Vec<Option<Throttle>>>,
    events: Receiver<Event>,
    /// Failures detected on the write path, queued ahead of the socket
    /// readers' own Down reports.
    pending: VecDeque<Event>,
    streams: usize,
    /// Broadcast membership: dormant spares and drained actors keep their
    /// sockets but receive no delta stream until admitted.
    active: Vec<bool>,
}

impl TcpHub {
    fn cut(&mut self, actor: usize, reason: String) {
        if let Some(socks) = self.writers[actor].take() {
            for s in &socks {
                let _ = s.shutdown(Shutdown::Both);
            }
            self.pending.push_back(Event::Down { actor: actor as u32, reason });
        }
    }
}

impl HubEndpoint for TcpHub {
    fn send(&mut self, actor: u32, msg: Msg) -> Result<(), Closed> {
        let a = actor as usize;
        let Some(socks) = self.writers.get_mut(a).and_then(|w| w.as_mut()) else {
            return Err(Closed);
        };
        match write_msg(&mut socks[0], &msg) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.cut(a, format!("write to actor {actor} failed: {e:#}"));
                Err(Closed)
            }
        }
    }

    fn broadcast_seg(&mut self, seg: Segment) {
        let stripe = stream_for(seg.seq, self.streams);
        // Serialize once, fan the same frame out to every live actor.
        let frame = Msg::Seg(seg).to_frame();
        let mut dead: Vec<(usize, String)> = Vec::new();
        for (a, slot) in self.writers.iter_mut().enumerate() {
            if !self.active.get(a).copied().unwrap_or(true) {
                continue;
            }
            let Some(socks) = slot.as_mut() else { continue };
            if let Some(t) = self.throttles[a][stripe].as_mut() {
                t.pace(frame.len());
            }
            if let Err(e) = socks[stripe].write_all(&frame) {
                dead.push((a, format!("segment push to actor {a} failed: {e}")));
            }
        }
        for (a, reason) in dead {
            self.cut(a, reason);
        }
    }

    fn poll(&mut self, timeout: Duration) -> Polled {
        if let Some(e) = self.pending.pop_front() {
            return Polled::Event(e);
        }
        match self.events.recv_timeout(timeout) {
            Ok(e) => Polled::Event(e),
            Err(RecvTimeoutError::Timeout) => Polled::TimedOut,
            Err(RecvTimeoutError::Disconnected) => Polled::Closed,
        }
    }

    fn set_active(&mut self, actor: u32, active: bool) {
        if let Some(a) = self.active.get_mut(actor as usize) {
            *a = active;
        }
    }

    fn shutdown(&mut self) {
        for slot in &mut self.writers {
            if let Some(mut socks) = slot.take() {
                let _ = write_msg(&mut socks[0], &Msg::Bye);
                // Explicit shutdown, not just drop: the hub's per-socket
                // reader threads hold fd clones, so dropping the write
                // halves alone would never send FIN — and a *stalled*
                // actor (which ignores the Bye) would block the scope
                // join forever. shutdown() closes the connection for all
                // clones: queued data (the Bye) flushes, then EOF
                // unblocks every reader on both sides.
                for s in &socks {
                    let _ = s.shutdown(Shutdown::Both);
                }
            }
        }
    }
}

/// One actor's "process": connects its stripes, bridges sockets to the
/// backend-agnostic runner, and injects configured failures.
fn actor_shell(
    addr: SocketAddr,
    actor: u32,
    streams: usize,
    kill: Option<KillSpec>,
    runner: ActorRunner<'_>,
) {
    let launched = (|| -> Result<TcpActorEndpoint> {
        let mut socks = Vec::with_capacity(streams);
        for _ in 0..streams {
            let mut s = TcpStream::connect(addr).context("connect to hub")?;
            s.set_nodelay(true)?;
            // Raw handshake frame: binds this socket to (actor, stripe).
            write_msg(&mut s, &Msg::Hello { actor, prior_tau: 1000.0 })?;
            socks.push(s);
        }
        let (in_tx, in_rx) = channel::<Msg>();
        for s in &socks {
            let rd = s.try_clone()?;
            let tx = in_tx.clone();
            // Readers drain unconditionally (even mid-generation and in
            // Stall mode), so hub writes never block on a slow actor.
            std::thread::spawn(move || shell_reader(rd, tx));
        }
        let ctrl = socks.remove(0);
        Ok(TcpActorEndpoint {
            actor,
            rx: in_rx,
            ctrl,
            extra: socks,
            kill,
            stalled: false,
            preempt_deadline: None,
        })
    })();
    let Ok(mut ep) = launched else {
        // Connect failed: the hub's accept loop times out and reports.
        return;
    };
    // Runner errors/panics surface at the hub as socket EOF -> Down.
    let _ = catch_unwind(AssertUnwindSafe(|| runner(actor, &mut ep)));
}

fn shell_reader(mut sock: TcpStream, tx: Sender<Msg>) {
    loop {
        match read_msg(&mut sock) {
            Ok(msg) => {
                if tx.send(msg).is_err() {
                    return;
                }
            }
            Err(_) => return, // hub closed: dropping tx unblocks the worker
        }
    }
}

struct TcpActorEndpoint {
    actor: u32,
    rx: Receiver<Msg>,
    /// Stripe-0 write half (all actor→hub traffic).
    ctrl: TcpStream,
    /// Stripes 1..: held so an injected Crash can slam them shut.
    extra: Vec<TcpStream>,
    kill: Option<KillSpec>,
    stalled: bool,
    /// Hard-kill time of an in-flight preemption warning.
    preempt_deadline: Option<Instant>,
}

impl TcpActorEndpoint {
    fn slam(&mut self) -> Closed {
        let _ = self.ctrl.shutdown(Shutdown::Both);
        for s in &self.extra {
            let _ = s.shutdown(Shutdown::Both);
        }
        Closed
    }

    /// Apply fault injection; `Ok(None)` means the message was swallowed
    /// (stalled) and the caller should keep receiving.
    fn intercept(&mut self, msg: Msg) -> Result<Option<Msg>, Closed> {
        if let Some(k) = self.kill {
            if matches!(&msg, Msg::Job { version, .. } if *version >= k.at_version) {
                match k.mode {
                    KillMode::Crash => return Err(self.slam()),
                    KillMode::Stall => self.stalled = true,
                    KillMode::Preempt { warn_ms } => {
                        if self.preempt_deadline.is_none() {
                            // The spot warning: it shares the FIFO control
                            // stream with the eventual EOF, so the hub is
                            // guaranteed to see the warning first.
                            let _ = write_msg(
                                &mut self.ctrl,
                                &Msg::Draining { actor: self.actor },
                            );
                            if warn_ms == 0 {
                                // Notice too short to act on: die before
                                // the trigger job is even seen, leaving
                                // its leases to the reissue path.
                                return Err(self.slam());
                            }
                            self.preempt_deadline =
                                Some(Instant::now() + Duration::from_millis(warn_ms));
                        }
                    }
                }
            }
        }
        if self.preempt_deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(self.slam());
        }
        if self.stalled {
            return Ok(None);
        }
        Ok(Some(msg))
    }
}

impl ActorEndpoint for TcpActorEndpoint {
    fn recv(&mut self) -> Result<Msg, Closed> {
        loop {
            // A pending hard kill bounds the wait so the deadline fires
            // even while the hub has nothing to say.
            let msg = match self.preempt_deadline {
                None => self.rx.recv().map_err(|_| Closed)?,
                Some(d) => {
                    let left = d.saturating_duration_since(Instant::now());
                    match self.rx.recv_timeout(left) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => return Err(self.slam()),
                        Err(RecvTimeoutError::Disconnected) => return Err(Closed),
                    }
                }
            };
            if let Some(m) = self.intercept(msg)? {
                return Ok(m);
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<Msg>, Closed> {
        loop {
            match self.rx.try_recv() {
                Ok(msg) => {
                    if let Some(m) = self.intercept(msg)? {
                        return Ok(Some(m));
                    }
                }
                Err(TryRecvError::Empty) => {
                    if self.preempt_deadline.is_some_and(|d| Instant::now() >= d) {
                        return Err(self.slam());
                    }
                    return Ok(None);
                }
                Err(TryRecvError::Disconnected) => return Err(Closed),
            }
        }
    }

    fn send(&mut self, msg: Msg) -> Result<(), Closed> {
        if self.preempt_deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(self.slam());
        }
        if self.stalled {
            return Ok(()); // partitioned: output is blackholed too
        }
        write_msg(&mut self.ctrl, &msg).map_err(|_| Closed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal worker protocol over real sockets: hello, echo commits as
    /// acks, report segment count, exit on Bye.
    fn echo_runner(actor: u32, ep: &mut dyn ActorEndpoint) -> Result<(), String> {
        ep.send(Msg::Hello { actor, prior_tau: 1000.0 }).map_err(|_| "hub gone")?;
        let mut segs = 0i32;
        loop {
            match ep.recv() {
                Ok(Msg::Seg(_)) => segs += 1,
                Ok(Msg::Commit { version }) => {
                    ep.send(Msg::RolloutResult {
                        actor,
                        prompt_id: 0,
                        version,
                        hash: [0u8; 32],
                        reward: 0.0,
                        tokens: vec![segs],
                    })
                    .map_err(|_| "hub gone")?;
                    ep.send(Msg::Activated { actor, version, hash: [0u8; 32] })
                        .map_err(|_| "hub gone")?;
                }
                Ok(Msg::Bye) | Err(Closed) => return Ok(()),
                Ok(_) => {}
            }
        }
    }

    #[test]
    fn loopback_multistream_round_trip() {
        let t = TcpTransport::new(TcpConfig { streams: 3, ..TcpConfig::default() });
        std::thread::scope(|scope| {
            let mut ep = t.launch(scope, 2, &echo_runner).unwrap();
            // Wait for both protocol-level hellos.
            let mut hellos = 0;
            while hellos < 2 {
                match ep.poll(Duration::from_secs(10)) {
                    Polled::Event(Event::Msg { msg: Msg::Hello { .. }, .. }) => hellos += 1,
                    other => panic!("want hello, got {other:?}"),
                }
            }
            for seq in 0..12u32 {
                ep.broadcast_seg(Segment { version: 1, seq, total: 12, payload: vec![7; 256] });
            }
            for a in 0..2 {
                ep.send(a, Msg::Commit { version: 1 }).unwrap();
            }
            let mut acks = 0;
            let mut counts = vec![0i32; 2];
            while acks < 2 {
                match ep.poll(Duration::from_secs(10)) {
                    Polled::Event(Event::Msg { actor, msg }) => match msg {
                        Msg::RolloutResult { tokens, .. } => counts[actor as usize] = tokens[0],
                        Msg::Activated { .. } => acks += 1,
                        other => panic!("unexpected {other:?}"),
                    },
                    other => panic!("poll: {other:?}"),
                }
            }
            // Every segment crossed the wire to every actor exactly once,
            // over 3 striped sockets.
            assert_eq!(counts, vec![12, 12]);
            ep.shutdown();
        });
    }

    #[test]
    fn crashed_actor_surfaces_as_down() {
        let t = TcpTransport::new(TcpConfig {
            streams: 1,
            bits_per_s: None,
            kills: vec![KillSpec { actor: 1, at_version: 1, mode: KillMode::Crash }],
        });
        std::thread::scope(|scope| {
            let mut ep = t.launch(scope, 2, &echo_runner).unwrap();
            let mut hellos = 0;
            while hellos < 2 {
                match ep.poll(Duration::from_secs(10)) {
                    Polled::Event(Event::Msg { msg: Msg::Hello { .. }, .. }) => hellos += 1,
                    other => panic!("want hello, got {other:?}"),
                }
            }
            // Job v1 triggers the injected crash on actor 1.
            ep.send(1, Msg::Job { version: 1, rng_seed: 0, prompt_ids: vec![9] }).unwrap();
            loop {
                match ep.poll(Duration::from_secs(10)) {
                    Polled::Event(Event::Down { actor: 1, .. }) => break,
                    Polled::Event(_) => continue,
                    other => panic!("want down, got {other:?}"),
                }
            }
            // The survivor still works.
            ep.send(0, Msg::Commit { version: 1 }).unwrap();
            loop {
                match ep.poll(Duration::from_secs(10)) {
                    Polled::Event(Event::Msg { actor: 0, msg: Msg::Activated { .. } }) => break,
                    Polled::Event(_) => continue,
                    other => panic!("poll: {other:?}"),
                }
            }
            ep.shutdown();
        });
    }
}
