//! Relay node logic: regional seed actor that receives delta segments from
//! the Trainer and forwards them to peer actors on arrival (§5.2
//! "relay-based fanout" — cut-through, not store-and-forward).
//!
//! Transport-agnostic: the real runtime (`rt/`) plugs TCP writers in as
//! `SegmentSink`s, tests plug in vectors. The relay also *stages the delta
//! itself* (it is a dual-role node: rollout actor + regional proxy).

use super::reassembly::{AcceptError, Reassembler};
use super::segment::Segment;

/// Receiver of forwarded segments (a peer actor connection).
pub trait SegmentSink {
    fn send_segment(&mut self, seg: &Segment) -> Result<(), String>;
}

impl SegmentSink for Vec<Segment> {
    fn send_segment(&mut self, seg: &Segment) -> Result<(), String> {
        self.push(seg.clone());
        Ok(())
    }
}

/// State machine of one relay for one checkpoint version.
pub struct RelayNode {
    reasm: Reassembler,
    forwarded: u64,
    forward_failures: u64,
}

impl RelayNode {
    pub fn new(version: u64) -> RelayNode {
        RelayNode { reasm: Reassembler::new(version), forwarded: 0, forward_failures: 0 }
    }

    pub fn version(&self) -> u64 {
        self.reasm.version()
    }

    /// Handle one incoming segment: forward to every peer immediately
    /// (cut-through), then stage locally. Duplicate segments are staged
    /// (idempotently) but *not* re-forwarded, so retries cannot amplify.
    ///
    /// The segment is classified with a read-only [`Reassembler::precheck`]
    /// first, so peers forward from the *borrowed* segment and staging then
    /// takes it by move — no payload copy anywhere on the fanout path.
    pub fn on_segment<S: SegmentSink>(
        &mut self,
        seg: Segment,
        peers: &mut [S],
    ) -> Result<(), AcceptError> {
        let is_dup = self.reasm.precheck(&seg)?;
        if !is_dup {
            for p in peers.iter_mut() {
                match p.send_segment(&seg) {
                    Ok(()) => self.forwarded += 1,
                    Err(_) => self.forward_failures += 1,
                }
            }
        }
        self.reasm.accept(seg)
    }

    pub fn is_staged(&self) -> bool {
        self.reasm.is_complete()
    }

    pub fn progress(&self) -> f64 {
        self.reasm.progress()
    }

    pub fn forwarded(&self) -> u64 {
        self.forwarded
    }

    pub fn forward_failures(&self) -> u64 {
        self.forward_failures
    }

    /// Finish staging: produce the verified checkpoint bytes.
    pub fn into_staged_bytes(self) -> Option<Vec<u8>> {
        self.reasm.assemble()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::segment::split_into_segments;
    use crate::util::Rng;

    fn segments(version: u64, n_bytes: usize, seg: usize) -> Vec<Segment> {
        let bytes: Vec<u8> = (0..n_bytes).map(|i| (i * 31) as u8).collect();
        split_into_segments(version, &bytes, seg)
    }

    #[test]
    fn forwards_each_segment_to_every_peer_once() {
        let segs = segments(3, 1000, 100);
        let mut relay = RelayNode::new(3);
        let mut peers = vec![Vec::new(), Vec::new(), Vec::new()];
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        assert!(relay.is_staged());
        assert_eq!(relay.forwarded(), (segs.len() * 3) as u64);
        for p in &peers {
            assert_eq!(p, &segs);
        }
    }

    #[test]
    fn duplicates_staged_but_not_reforwarded() {
        let segs = segments(1, 500, 100);
        let mut relay = RelayNode::new(1);
        let mut peers = vec![Vec::new()];
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        // Retry the whole stream.
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        assert_eq!(peers[0].len(), segs.len(), "no duplicate forwarding");
        assert!(relay.is_staged());
    }

    #[test]
    fn peers_receive_out_of_order_stream_and_reassemble() {
        let segs = {
            let mut s = segments(9, 2000, 128);
            Rng::new(5).shuffle(&mut s);
            s
        };
        let mut relay = RelayNode::new(9);
        let mut peers = vec![Vec::new()];
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        let mut peer_reasm = Reassembler::new(9);
        for s in peers[0].drain(..) {
            peer_reasm.accept(s).unwrap();
        }
        assert!(peer_reasm.is_complete());
        assert_eq!(peer_reasm.assemble().unwrap(), relay.into_staged_bytes().unwrap());
    }

    #[test]
    fn wrong_version_segments_rejected_not_forwarded() {
        let mut relay = RelayNode::new(2);
        let mut peers = vec![Vec::new()];
        let seg = segments(7, 100, 100).remove(0);
        assert!(relay.on_segment(seg, &mut peers).is_err());
        assert!(peers[0].is_empty());
    }

    struct FailingSink;
    impl SegmentSink for FailingSink {
        fn send_segment(&mut self, _s: &Segment) -> Result<(), String> {
            Err("broken pipe".into())
        }
    }

    #[test]
    fn peer_failure_does_not_stop_staging() {
        let segs = segments(4, 800, 100);
        let mut relay = RelayNode::new(4);
        let mut peers = vec![FailingSink];
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        assert!(relay.is_staged(), "relay still stages despite dead peer");
        assert_eq!(relay.forward_failures(), segs.len() as u64);
    }
}
