//! The transport API: one [`Transport`] trait, one executor, N backends.
//!
//! Every hub↔actor interaction in the runtime — segment push, staged
//! commit, job dispatch, rollout results, activation acks, membership —
//! is a [`Msg`] flowing through two handle types:
//!
//! * [`ActorEndpoint`] — an actor worker's view: blocking/non-blocking
//!   receive of hub messages, send of replies;
//! * [`HubEndpoint`] — the Trainer Hub's view over the whole fleet:
//!   per-actor send, one-call segment fan-out, and a single merged
//!   [`Event`] stream that also surfaces link failures ([`Event::Down`])
//!   so the ledger's lease machinery (§5.4) can requeue orphaned work.
//!
//! A [`Transport`] launches the actor side of a backend (worker threads,
//! netsim-reordered channels, or real loopback sockets) around a
//! backend-agnostic *runner* — `rt::pipeline`'s actor worker — and hands
//! the executor its hub endpoint. The executor code path is therefore
//! identical across:
//!
//! * [`InProcTransport`] — the current mpsc mailboxes, zero-copy message
//!   passing, optional regional relay forwarding (the default);
//! * [`SimTransport`] — delta streams routed through
//!   [`netsim::stripes::deliver_striped`] per
//!   [`DistributionPlan`](crate::transport::DistributionPlan)-style
//!   relay legs, so WAN arrival reordering exercises the staging decoder
//!   inside the real executor;
//! * [`TcpTransport`](crate::transport::tcp::TcpTransport) — actual
//!   framed sockets with throttled writers and real failure semantics
//!   (see `transport/tcp.rs`).
//!
//! [`netsim::stripes::deliver_striped`]: crate::netsim::deliver_striped

use crate::netsim::{deliver_striped, Link};
use crate::rt::net::Msg;
use crate::rt::DistributionSpec;
use crate::transport::Segment;
use crate::util::Rng;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::Scope;
use std::time::Duration;

/// The far side of a channel is gone (worker exited, socket closed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Closed;

/// What the hub's merged delivery stream yields.
#[derive(Debug)]
pub enum Event {
    /// A message arrived from `actor`.
    Msg { actor: u32, msg: Msg },
    /// The link to `actor` died: worker panic/error, socket EOF or reset.
    /// The failure surface the ledger's leases exist for.
    Down { actor: u32, reason: String },
}

/// Outcome of one [`HubEndpoint::poll`] call.
#[derive(Debug)]
pub enum Polled {
    Event(Event),
    /// Nothing arrived within the timeout (the hub's cue to run a lease
    /// expiry sweep).
    TimedOut,
    /// Every actor link has shut down.
    Closed,
}

/// An actor worker's communication handle. `try_recv` lets the worker
/// drain staging segments and parked commits at inter-batch safe points
/// without blocking generation.
pub trait ActorEndpoint: Send {
    fn recv(&mut self) -> Result<Msg, Closed>;
    fn try_recv(&mut self) -> Result<Option<Msg>, Closed>;
    fn send(&mut self, msg: Msg) -> Result<(), Closed>;
}

/// The Trainer Hub's communication handle over the whole actor fleet.
pub trait HubEndpoint {
    /// Send a control message (job, commit, shutdown) to one actor.
    fn send(&mut self, actor: u32, msg: Msg) -> Result<(), Closed>;

    /// Fan one delta segment out to every actor. The backend owns the
    /// route: direct mailbox pushes, relay-tree forwarding, striped WAN
    /// arrival ordering, or throttled multi-stream sockets.
    fn broadcast_seg(&mut self, seg: Segment);

    /// Wait up to `timeout` for the next delivery.
    fn poll(&mut self, timeout: Duration) -> Polled;

    /// Include/exclude `actor` from `broadcast_seg` fan-out. Elastic
    /// membership: a dormant spare (launched but not yet joined) and a
    /// drained actor must not receive delta streams — a joiner earns the
    /// live stream only once admitted, and its catch-up happens through
    /// explicit per-actor bootstrap sends. Direct `send` is unaffected
    /// (the hub still needs to `Invite`/`Drain` inactive actors).
    fn set_active(&mut self, actor: u32, active: bool);

    /// Orderly shutdown: `Bye` to every live actor, then close links.
    fn shutdown(&mut self);
}

/// The backend-agnostic actor worker a [`Transport`] drives: the same
/// function runs on an in-process thread, behind the netsim reorder
/// model, and on the far side of a TCP socket. A `String` error becomes
/// an [`Event::Down`] at the hub.
pub type ActorRunner<'a> = &'a (dyn Fn(u32, &mut dyn ActorEndpoint) -> Result<(), String> + Sync);

/// A communication backend. `launch` spawns one actor runtime per id in
/// `0..n` onto `scope`, each driving `runner` with its endpoint, and
/// returns the hub's handle. Worker panics and errors surface as
/// [`Event::Down`], never as a hung hub.
pub trait Transport {
    fn name(&self) -> &'static str;

    fn launch<'scope, 'env>(
        &'env self,
        scope: &'scope Scope<'scope, 'env>,
        n: usize,
        runner: ActorRunner<'env>,
    ) -> Result<Box<dyn HubEndpoint + 'env>>;
}

// ---------------------------------------------------------------------
// InProc backend
// ---------------------------------------------------------------------

/// Zero-copy in-process backend: one mpsc mailbox per actor worker, the
/// merged reply stream on a shared channel. With a non-flat
/// [`DistributionSpec`] the hub pushes each segment once per region (to
/// the relay's mailbox) and relay endpoints forward to their peers
/// cut-through — the in-process mirror of the WAN tree.
pub struct InProcTransport {
    spec: DistributionSpec,
}

impl InProcTransport {
    pub fn new(spec: Option<DistributionSpec>) -> InProcTransport {
        InProcTransport { spec: spec.unwrap_or_default() }
    }
}

struct InProcEndpoint {
    actor: u32,
    rx: Receiver<Msg>,
    events: Sender<Event>,
    /// Regional peers this endpoint relays segments to (cut-through,
    /// before local staging, so peers never wait on the relay's decode).
    forwards: Vec<Sender<Msg>>,
}

impl InProcEndpoint {
    fn intercept(&mut self, msg: Msg) -> Msg {
        if let Msg::Seg(seg) = &msg {
            // Send failures mean the peer exited; its own Down event
            // reports the cause, so drops here are not amplified.
            for tx in &self.forwards {
                let _ = tx.send(Msg::Seg(seg.clone()));
            }
        }
        msg
    }
}

impl ActorEndpoint for InProcEndpoint {
    fn recv(&mut self) -> Result<Msg, Closed> {
        let msg = self.rx.recv().map_err(|_| Closed)?;
        Ok(self.intercept(msg))
    }

    fn try_recv(&mut self) -> Result<Option<Msg>, Closed> {
        match self.rx.try_recv() {
            Ok(msg) => Ok(Some(self.intercept(msg))),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(Closed),
        }
    }

    fn send(&mut self, msg: Msg) -> Result<(), Closed> {
        self.events
            .send(Event::Msg { actor: self.actor, msg })
            .map_err(|_| Closed)
    }
}

struct InProcHub {
    /// Per-actor mailbox senders; `None` after shutdown took them.
    to: Vec<Option<Sender<Msg>>>,
    events: Receiver<Event>,
    /// Relay wiring: flat = hub pushes to everyone; tree = one push per
    /// region (the relay) with direct-fetch fallback for its peers.
    spec: DistributionSpec,
    /// Global actor indices per region (relay first), precomputed once —
    /// the topology is fixed for the run and `broadcast_seg` sits on
    /// the per-segment delta hot path.
    region_members: Vec<Vec<usize>>,
    /// Broadcast membership: dormant spares and drained actors are
    /// excluded from segment fan-out (elastic joins/leaves flip this).
    active: Vec<bool>,
}

impl InProcHub {
    fn new(to: Vec<Option<Sender<Msg>>>, events: Receiver<Event>, spec: DistributionSpec) -> InProcHub {
        let region_members: Vec<Vec<usize>> = (0..spec.n_regions())
            .map(|region| {
                spec.region_of
                    .iter()
                    .enumerate()
                    .filter(|&(_, &r)| r == region)
                    .map(|(i, _)| i)
                    .collect()
            })
            .collect();
        let active = vec![true; to.len()];
        InProcHub { to, events, spec, region_members, active }
    }

    fn seg_to(&self, actor: usize, seg: &Segment) -> bool {
        match self.to.get(actor).and_then(|t| t.as_ref()) {
            Some(tx) => tx.send(Msg::Seg(seg.clone())).is_ok(),
            None => false,
        }
    }
}

impl HubEndpoint for InProcHub {
    fn send(&mut self, actor: u32, msg: Msg) -> Result<(), Closed> {
        match self.to.get(actor as usize).and_then(|t| t.as_ref()) {
            Some(tx) => tx.send(msg).map_err(|_| Closed),
            None => Err(Closed),
        }
    }

    fn broadcast_seg(&mut self, seg: Segment) {
        if self.spec.is_flat() {
            // Move the segment into its last target; clone for the rest.
            let live: Vec<&Sender<Msg>> = self
                .to
                .iter()
                .enumerate()
                .filter(|&(i, _)| self.active.get(i).copied().unwrap_or(true))
                .filter_map(|(_, t)| t.as_ref())
                .collect();
            let Some((last, rest)) = live.split_last() else { return };
            for tx in rest {
                let _ = tx.send(Msg::Seg(seg.clone()));
            }
            let _ = last.send(Msg::Seg(seg));
            return;
        }
        // Tree: one push per region, to the relay (its endpoint forwards
        // to peers cut-through). If the relay's mailbox is already
        // disconnected, the rest of the stream goes straight to its peers
        // (§5.4's direct-fetch). Note this cannot recover segments still
        // queued in the dropped mailbox — the executor therefore treats a
        // lost relay as fatal (`rt/pipeline.rs` `fail_actor`) rather than
        // risking a stranded region.
        for members in &self.region_members {
            let Some(&relay) = members.first() else { continue };
            if !self.seg_to(relay, &seg) {
                for &peer in &members[1..] {
                    self.seg_to(peer, &seg);
                }
            }
        }
    }

    fn poll(&mut self, timeout: Duration) -> Polled {
        match self.events.recv_timeout(timeout) {
            Ok(e) => Polled::Event(e),
            Err(RecvTimeoutError::Timeout) => Polled::TimedOut,
            Err(RecvTimeoutError::Disconnected) => Polled::Closed,
        }
    }

    fn set_active(&mut self, actor: u32, active: bool) {
        if let Some(a) = self.active.get_mut(actor as usize) {
            *a = active;
        }
    }

    fn shutdown(&mut self) {
        for slot in &mut self.to {
            if let Some(tx) = slot.take() {
                let _ = tx.send(Msg::Bye);
            }
            // Dropping the sender disconnects the mailbox, so a worker
            // blocked in recv() exits even if it missed the Bye.
        }
    }
}

/// Shared by InProc and Sim: create the mailboxes, spawn one worker
/// thread per actor around `runner` with panic/error → `Down` wrapping.
fn launch_workers<'scope, 'env>(
    scope: &'scope Scope<'scope, 'env>,
    n: usize,
    runner: ActorRunner<'env>,
    spec: &DistributionSpec,
) -> (Vec<Option<Sender<Msg>>>, Receiver<Event>) {
    let (ev_tx, ev_rx) = channel::<Event>();
    let mut to: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        to.push(tx);
        rxs.push(Some(rx));
    }
    for (i, slot) in rxs.iter_mut().enumerate() {
        let rx = slot.take().expect("receiver consumed once");
        let forwards: Vec<Sender<Msg>> = spec
            .forward_targets(i)
            .into_iter()
            .map(|j| to[j].clone())
            .collect();
        let actor = i as u32;
        let mut ep = InProcEndpoint { actor, rx, events: ev_tx.clone(), forwards };
        let down_tx = ev_tx.clone();
        scope.spawn(move || {
            let reason = match catch_unwind(AssertUnwindSafe(|| runner(actor, &mut ep))) {
                Ok(Ok(())) => return,
                Ok(Err(msg)) => msg,
                Err(_) => format!("actor {actor} worker panicked"),
            };
            let _ = down_tx.send(Event::Down { actor, reason });
        });
    }
    (to.into_iter().map(Some).collect(), ev_rx)
}

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn launch<'scope, 'env>(
        &'env self,
        scope: &'scope Scope<'scope, 'env>,
        n: usize,
        runner: ActorRunner<'env>,
    ) -> Result<Box<dyn HubEndpoint + 'env>> {
        let (to, events) = launch_workers(scope, n, runner, &self.spec);
        Ok(Box::new(InProcHub::new(to, events, self.spec.clone())))
    }
}

// ---------------------------------------------------------------------
// Sim backend
// ---------------------------------------------------------------------

/// Network model for [`SimTransport`]: the fleet's region layout and the
/// per-region WAN legs delta streams traverse.
#[derive(Clone, Debug)]
pub struct SimNetConfig {
    /// Region index of each actor (defines fleet size and the relay
    /// tree's legs; actors of one region share its arrival order).
    pub region_of: Vec<usize>,
    /// Hub→relay WAN link per region.
    pub links: Vec<Link>,
    /// Stripe (parallel stream) count per region's WAN leg.
    pub streams: Vec<usize>,
    /// Seed for per-(version, region) arrival jitter — the reorder is
    /// fully deterministic.
    pub seed: u64,
}

impl SimNetConfig {
    /// Single-region fleet over one emulated WAN link.
    pub fn single_region(n_actors: usize, link: Link, streams: usize, seed: u64) -> SimNetConfig {
        SimNetConfig {
            region_of: vec![0; n_actors],
            links: vec![link],
            streams: vec![streams.max(1)],
            seed,
        }
    }

    /// Model a `wan-N` preset: actors contiguous per region, one link per
    /// region from its profile, stripes sized to the link's
    /// bandwidth-delay product.
    pub fn from_preset(preset: &crate::config::WanPreset, seed: u64) -> SimNetConfig {
        let mut region_of = Vec::new();
        let mut links = Vec::new();
        let mut streams = Vec::new();
        for (r, profile) in preset.regions.iter().enumerate() {
            for _ in 0..preset.actors_per_region {
                region_of.push(r);
            }
            let link = Link::from_profile(profile);
            streams.push(crate::transport::stripe::stripes_for_link(&link));
            links.push(link);
        }
        SimNetConfig { region_of, links, streams, seed }
    }

    pub fn n_regions(&self) -> usize {
        self.region_of.iter().max().map_or(0, |m| m + 1)
    }
}

/// Backend that routes every delta stream through the netsim WAN model:
/// segments buffer at the hub edge, and when the version's `Commit` is
/// pushed each region's stream is released in the arrival order
/// [`deliver_striped`] computes for its relay leg (per-stripe FIFO,
/// jittered rates). Every member of a region observes the relay's
/// arrival order — the cut-through forwarding contract. Control traffic
/// (jobs, commits, results, acks) is not reordered, exactly like TCP
/// control streams. Time is *modeled*, not slept: the reorder is real,
/// the latency is netsim's business.
pub struct SimTransport {
    pub net: SimNetConfig,
}

impl SimTransport {
    pub fn new(net: SimNetConfig) -> SimTransport {
        SimTransport { net }
    }
}

struct SimHub {
    inner: InProcHub,
    net: SimNetConfig,
    /// The in-flight version's segment stream (one copy, fanned out at
    /// flush).
    buf: Vec<Segment>,
    flushed: u64,
}

impl SimHub {
    /// Release the buffered stream of `version` in per-region WAN arrival
    /// order. Idempotent per version (the hub pushes one Commit per
    /// actor; the first triggers the flush).
    fn flush(&mut self, version: u64) {
        if version <= self.flushed || self.buf.is_empty() {
            return;
        }
        self.flushed = version;
        let sizes: Vec<u64> = self.buf.iter().map(|s| s.payload.len() as u64).collect();
        for region in 0..self.net.n_regions() {
            let members: Vec<usize> = self
                .net
                .region_of
                .iter()
                .enumerate()
                .filter(|&(_, &r)| r == region)
                .map(|(i, _)| i)
                .collect();
            if members.is_empty() {
                continue;
            }
            let mut rng = Rng::new(
                self.net
                    .seed
                    .wrapping_add(version.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                    ^ (region as u64).wrapping_mul(0x2545_F491_4F6C_DD1D),
            );
            let arrivals =
                deliver_striped(&self.net.links[region], &sizes, self.net.streams[region], &mut rng);
            for a in &arrivals {
                for &m in &members {
                    let _ = self.inner.send(m as u32, Msg::Seg(self.buf[a.index].clone()));
                }
            }
        }
        self.buf.clear();
    }
}

impl HubEndpoint for SimHub {
    fn send(&mut self, actor: u32, msg: Msg) -> Result<(), Closed> {
        if let Msg::Commit { version } = &msg {
            self.flush(*version);
        }
        self.inner.send(actor, msg)
    }

    fn broadcast_seg(&mut self, seg: Segment) {
        self.buf.push(seg);
    }

    fn poll(&mut self, timeout: Duration) -> Polled {
        self.inner.poll(timeout)
    }

    fn set_active(&mut self, actor: u32, active: bool) {
        self.inner.set_active(actor, active);
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }
}

impl Transport for SimTransport {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn launch<'scope, 'env>(
        &'env self,
        scope: &'scope Scope<'scope, 'env>,
        n: usize,
        runner: ActorRunner<'env>,
    ) -> Result<Box<dyn HubEndpoint + 'env>> {
        anyhow::ensure!(
            self.net.region_of.len() == n,
            "sim net config covers {} actors but the run has {n}",
            self.net.region_of.len()
        );
        anyhow::ensure!(
            self.net.links.len() >= self.net.n_regions()
                && self.net.streams.len() >= self.net.n_regions(),
            "sim net config needs one link + stripe count per region"
        );
        // Relay forwarding is modeled in the arrival order (every region
        // member sees the relay-leg order), so workers get no forwards
        // and the inner hub is flat.
        let (to, events) = launch_workers(scope, n, runner, &DistributionSpec::default());
        let inner = InProcHub::new(to, events, DistributionSpec::default());
        Ok(Box::new(SimHub { inner, net: self.net.clone(), buf: Vec::new(), flushed: 0 }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::regions;

    /// Echo worker: acks Hello, reflects Commit as Activated, counts Seg
    /// arrivals into RolloutResult-shaped probes, exits on Bye.
    fn echo_runner(actor: u32, ep: &mut dyn ActorEndpoint) -> Result<(), String> {
        ep.send(Msg::Hello { actor, prior_tau: 1000.0 }).map_err(|_| "hub gone")?;
        let mut seg_seqs: Vec<u32> = Vec::new();
        loop {
            match ep.recv() {
                Ok(Msg::Seg(seg)) => seg_seqs.push(seg.seq),
                Ok(Msg::Commit { version }) => {
                    // Report observed arrival order through the tokens
                    // field so the test can assert on it.
                    ep.send(Msg::RolloutResult {
                        actor,
                        prompt_id: 0,
                        version,
                        hash: [0u8; 32],
                        reward: 0.0,
                        tokens: seg_seqs.iter().map(|&s| s as i32).collect(),
                    })
                    .map_err(|_| "hub gone")?;
                    ep.send(Msg::Activated { actor, version, hash: [0u8; 32] })
                        .map_err(|_| "hub gone")?;
                }
                Ok(Msg::Bye) | Err(Closed) => return Ok(()),
                Ok(_) => {}
            }
        }
    }

    fn segs(n: u32) -> Vec<Segment> {
        (0..n)
            .map(|seq| Segment { version: 1, seq, total: n, payload: vec![seq as u8; 64] })
            .collect()
    }

    fn collect_orders(
        ep: &mut dyn HubEndpoint,
        n: usize,
    ) -> (Vec<Vec<i32>>, usize) {
        let mut orders: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut acks = 0;
        let mut hellos = 0;
        while acks < n {
            match ep.poll(Duration::from_secs(5)) {
                Polled::Event(Event::Msg { actor, msg }) => match msg {
                    Msg::Hello { .. } => hellos += 1,
                    Msg::RolloutResult { tokens, .. } => orders[actor as usize] = tokens,
                    Msg::Activated { .. } => acks += 1,
                    other => panic!("unexpected {other:?}"),
                },
                other => panic!("poll: {other:?}"),
            }
        }
        (orders, hellos)
    }

    #[test]
    fn inproc_round_trip_with_relay_forwarding() {
        let spec = DistributionSpec { region_of: vec![0, 0, 1] };
        let t = InProcTransport::new(Some(spec));
        std::thread::scope(|scope| {
            let mut ep = t.launch(scope, 3, &echo_runner).unwrap();
            for s in segs(5) {
                ep.broadcast_seg(s);
            }
            for a in 0..3 {
                ep.send(a, Msg::Commit { version: 1 }).unwrap();
            }
            let (orders, hellos) = collect_orders(ep.as_mut(), 3);
            assert_eq!(hellos, 3, "every worker said hello");
            // Relays (actors 0, 2) got direct pushes; peer 1 got relay
            // forwards — everyone saw the full stream exactly once.
            for (a, order) in orders.iter().enumerate() {
                assert_eq!(order, &vec![0, 1, 2, 3, 4], "actor {a}");
            }
            ep.shutdown();
        });
    }

    #[test]
    fn sim_reorders_deterministically_and_delivers_exactly_once() {
        let link = Link::from_profile(&regions::CANADA);
        let net = SimNetConfig::single_region(2, link, 4, 7);
        let run = || {
            let t = SimTransport::new(net.clone());
            std::thread::scope(|scope| {
                let mut ep = t.launch(scope, 2, &echo_runner).unwrap();
                for s in segs(24) {
                    ep.broadcast_seg(s);
                }
                for a in 0..2 {
                    ep.send(a, Msg::Commit { version: 1 }).unwrap();
                }
                let (orders, _) = collect_orders(ep.as_mut(), 2);
                ep.shutdown();
                orders
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed, same arrival order");
        // Exactly once, but NOT in send order (the WAN reorder is real).
        let mut sorted = a[0].clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..24).collect::<Vec<_>>());
        assert_ne!(a[0], (0..24).collect::<Vec<_>>(), "expected cross-stripe reorder");
        // Both region members observed the same (relay cut-through) order.
        assert_eq!(a[0], a[1]);
    }

    #[test]
    fn worker_error_surfaces_as_down_event() {
        let t = InProcTransport::new(None);
        let runner = |actor: u32, ep: &mut dyn ActorEndpoint| -> Result<(), String> {
            ep.send(Msg::Hello { actor, prior_tau: 1.0 }).map_err(|_| "hub gone")?;
            match ep.recv() {
                Ok(Msg::Commit { .. }) => Err("injected failure".to_string()),
                _ => Ok(()),
            }
        };
        std::thread::scope(|scope| {
            let mut ep = t.launch(scope, 1, &runner).unwrap();
            match ep.poll(Duration::from_secs(5)) {
                Polled::Event(Event::Msg { msg: Msg::Hello { .. }, .. }) => {}
                other => panic!("want hello, got {other:?}"),
            }
            ep.send(0, Msg::Commit { version: 1 }).unwrap();
            match ep.poll(Duration::from_secs(5)) {
                Polled::Event(Event::Down { actor: 0, reason }) => {
                    assert!(reason.contains("injected failure"));
                }
                other => panic!("want down, got {other:?}"),
            }
            ep.shutdown();
        });
    }
}
