//! Round-robin segment striping across S parallel streams (§5.2).
//!
//! Striping serves two purposes the paper calls out: it lifts aggregate
//! throughput past a single TCP stream's congestion-control ceiling, and a
//! loss-induced stall on one stream delays only that stream's segments.
//! Assignment must be a *deterministic function of seq* so a relay can
//! re-stripe without coordination.

use super::segment::Segment;

/// Assign segment `seq` to one of `streams` streams.
#[inline]
pub fn stream_for(seq: u32, streams: usize) -> usize {
    (seq as usize) % streams.max(1)
}

/// Partition segments into per-stream send queues, preserving seq order
/// within each stream.
pub fn stripe_round_robin(segments: Vec<Segment>, streams: usize) -> Vec<Vec<Segment>> {
    let s = streams.max(1);
    let mut queues: Vec<Vec<Segment>> = (0..s).map(|_| Vec::new()).collect();
    for seg in segments {
        queues[stream_for(seg.seq, s)].push(seg);
    }
    queues
}

/// Interleave per-stream queues back into arrival order assuming equal
/// stream rates — the order a receiver would observe segments (test and
/// simulation helper; reassembly does not depend on it).
pub fn interleave_arrival_order(queues: &[Vec<Segment>]) -> Vec<Segment> {
    let mut out = Vec::new();
    let mut cursors = vec![0usize; queues.len()];
    loop {
        let mut progressed = false;
        for (q, cur) in queues.iter().zip(cursors.iter_mut()) {
            if *cur < q.len() {
                out.push(q[*cur].clone());
                *cur += 1;
                progressed = true;
            }
        }
        if !progressed {
            return out;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::segment::split_into_segments;
    use crate::util::prop;

    fn segs(n: usize) -> Vec<Segment> {
        let bytes = vec![0u8; n * 10];
        split_into_segments(1, &bytes, 10)
    }

    #[test]
    fn round_robin_balances_counts() {
        let queues = stripe_round_robin(segs(10), 4);
        let counts: Vec<usize> = queues.iter().map(|q| q.len()).collect();
        assert_eq!(counts, vec![3, 3, 2, 2]);
    }

    #[test]
    fn zero_streams_treated_as_one() {
        let queues = stripe_round_robin(segs(5), 0);
        assert_eq!(queues.len(), 1);
        assert_eq!(queues[0].len(), 5);
    }

    #[test]
    fn assignment_is_deterministic_in_seq() {
        for seq in 0..100u32 {
            assert_eq!(stream_for(seq, 4), (seq % 4) as usize);
        }
    }

    #[test]
    fn prop_striping_is_a_partition() {
        prop::check("striping partitions segments exactly", 40, |rng| {
            let n = rng.range(1, 200);
            let s = rng.range(1, 9);
            let original = segs(n);
            let queues = stripe_round_robin(original.clone(), s);
            // Every segment appears exactly once, on its assigned stream.
            let mut seen = vec![false; n];
            for (si, q) in queues.iter().enumerate() {
                let mut last_seq = None;
                for seg in q {
                    assert_eq!(stream_for(seg.seq, s), si);
                    assert!(!seen[seg.seq as usize]);
                    seen[seg.seq as usize] = true;
                    // seq order preserved within a stream
                    if let Some(l) = last_seq {
                        assert!(seg.seq > l);
                    }
                    last_seq = Some(seg.seq);
                }
            }
            assert!(seen.into_iter().all(|x| x));
        });
    }

    #[test]
    fn interleave_emits_every_segment_once() {
        let queues = stripe_round_robin(segs(11), 3);
        let arr = interleave_arrival_order(&queues);
        assert_eq!(arr.len(), 11);
        let mut seqs: Vec<u32> = arr.iter().map(|s| s.seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..11).collect::<Vec<_>>());
    }
}
