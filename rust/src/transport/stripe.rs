//! Round-robin segment striping across S parallel streams (§5.2).
//!
//! Striping serves two purposes the paper calls out: it lifts aggregate
//! throughput past a single TCP stream's congestion-control ceiling, and a
//! loss-induced stall on one stream delays only that stream's segments.
//! Assignment must be a *deterministic function of seq* so a relay can
//! re-stripe without coordination.
//!
//! [`stripes_for_link`] sizes the stream count to the link's
//! bandwidth-delay product: each stream's congestion window sustains only
//! `w = MSS·C/√p` bytes of the BDP, so `S = ceil(BDP_eff / w)` streams are
//! needed before path capacity, not the per-stream ceiling, binds.

use super::segment::Segment;
use crate::netsim::link::PROTOCOL_EFFICIENCY;
use crate::netsim::Link;

/// Upper bound on per-link stripes: past this, connection and reassembly
/// overheads dominate any residual window gain (the paper evaluates 1–8;
/// Australia-class paths saturate well below 16).
pub const MAX_STRIPES: usize = 16;

/// Bandwidth-delay-product stripe sizing for one WAN leg.
///
/// A single TCP stream on a path with RTT `r` and residual loss `p`
/// sustains a congestion window of about `w = MSS·C/√p` bytes — a fixed
/// fraction of the link's bandwidth-delay product `B·r`. The number of
/// parallel streams that fills the pipe is therefore
/// `S = ceil(B·r / w) = ceil(B_eff / ceiling_bps)` — the two forms are
/// algebraically identical, and the second is what the Mathis model in
/// [`Link`] exposes directly. Lossless links need exactly one stream;
/// long-RTT lossy fat pipes are clamped at [`MAX_STRIPES`].
pub fn stripes_for_link(link: &Link) -> usize {
    let per_stream = link.single_stream_ceiling_bps();
    if per_stream <= 0.0 {
        return 1;
    }
    let target = link.capacity_bps * PROTOCOL_EFFICIENCY;
    ((target / per_stream).ceil() as usize).clamp(1, MAX_STRIPES)
}

/// Assign segment `seq` to one of `streams` streams.
#[inline]
pub fn stream_for(seq: u32, streams: usize) -> usize {
    (seq as usize) % streams.max(1)
}

/// Partition segments into per-stream send queues, preserving seq order
/// within each stream.
pub fn stripe_round_robin(segments: Vec<Segment>, streams: usize) -> Vec<Vec<Segment>> {
    let s = streams.max(1);
    let mut queues: Vec<Vec<Segment>> = (0..s).map(|_| Vec::new()).collect();
    for seg in segments {
        queues[stream_for(seg.seq, s)].push(seg);
    }
    queues
}

/// Interleave per-stream queues back into arrival order assuming equal
/// stream rates — the order a receiver would observe segments (test and
/// simulation helper; reassembly does not depend on it).
pub fn interleave_arrival_order(queues: &[Vec<Segment>]) -> Vec<Segment> {
    let mut out = Vec::new();
    let mut cursors = vec![0usize; queues.len()];
    loop {
        let mut progressed = false;
        for (q, cur) in queues.iter().zip(cursors.iter_mut()) {
            if *cur < q.len() {
                out.push(q[*cur].clone());
                *cur += 1;
                progressed = true;
            }
        }
        if !progressed {
            return out;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::segment::split_into_segments;
    use crate::util::prop;

    fn segs(n: usize) -> Vec<Segment> {
        let bytes = vec![0u8; n * 10];
        split_into_segments(1, &bytes, 10)
    }

    #[test]
    fn round_robin_balances_counts() {
        let queues = stripe_round_robin(segs(10), 4);
        let counts: Vec<usize> = queues.iter().map(|q| q.len()).collect();
        assert_eq!(counts, vec![3, 3, 2, 2]);
    }

    #[test]
    fn zero_streams_treated_as_one() {
        let queues = stripe_round_robin(segs(5), 0);
        assert_eq!(queues.len(), 1);
        assert_eq!(queues[0].len(), 5);
    }

    #[test]
    fn assignment_is_deterministic_in_seq() {
        for seq in 0..100u32 {
            assert_eq!(stream_for(seq, 4), (seq % 4) as usize);
        }
    }

    #[test]
    fn prop_striping_is_a_partition() {
        prop::check("striping partitions segments exactly", 40, |rng| {
            let n = rng.range(1, 200);
            let s = rng.range(1, 9);
            let original = segs(n);
            let queues = stripe_round_robin(original.clone(), s);
            // Every segment appears exactly once, on its assigned stream.
            let mut seen = vec![false; n];
            for (si, q) in queues.iter().enumerate() {
                let mut last_seq = None;
                for seg in q {
                    assert_eq!(stream_for(seg.seq, s), si);
                    assert!(!seen[seg.seq as usize]);
                    seen[seg.seq as usize] = true;
                    // seq order preserved within a stream
                    if let Some(l) = last_seq {
                        assert!(seg.seq > l);
                    }
                    last_seq = Some(seg.seq);
                }
            }
            assert!(seen.into_iter().all(|x| x));
        });
    }

    #[test]
    fn bdp_stripes_lossless_link_needs_one_stream() {
        // No loss: one stream already reaches protocol-efficiency capacity.
        let lan = Link::emulated(10e9, 0.001, 0.0);
        assert_eq!(stripes_for_link(&lan), 1);
        // Extreme low bandwidth: the Mathis ceiling exceeds the capacity,
        // so the capacity term binds and one stream suffices.
        let dialup = Link::emulated(56e3, 0.120, 1e-4);
        assert_eq!(stripes_for_link(&dialup), 1);
    }

    #[test]
    fn bdp_stripes_grow_with_bandwidth_delay_product_and_cap() {
        use crate::config::regions;
        // US-Canada: moderate BDP -> a couple of streams.
        let ca = Link::from_profile(&regions::CANADA);
        let s_ca = stripes_for_link(&ca);
        assert!((2..=4).contains(&s_ca), "canada stripes {s_ca}");
        // Australia: long RTT + loss -> more streams than Canada.
        let au = Link::from_profile(&regions::AUSTRALIA);
        assert!(stripes_for_link(&au) > s_ca);
        // Extreme high bandwidth on a long lossy path: the raw BDP formula
        // would ask for thousands of streams; the cap binds.
        let fat = Link::emulated(100e9, 0.150, 1e-4);
        assert_eq!(stripes_for_link(&fat), MAX_STRIPES);
    }

    #[test]
    fn bdp_stripes_saturate_the_link() {
        // The chosen count reaches the link's effective capacity, and one
        // fewer stream would not (when more than one is chosen at all).
        use crate::config::regions;
        for p in [regions::CANADA, regions::JAPAN, regions::AUSTRALIA] {
            let link = Link::from_profile(&p);
            let s = stripes_for_link(&link);
            let cap = link.capacity_bps * crate::netsim::link::PROTOCOL_EFFICIENCY;
            if s < MAX_STRIPES {
                assert!(link.effective_bps(s) >= cap - 1.0, "{}: {s} stripes", p.name);
            }
            if s > 1 {
                assert!(link.effective_bps(s - 1) < cap, "{}: {s} not minimal", p.name);
            }
        }
    }

    #[test]
    fn interleave_emits_every_segment_once() {
        let queues = stripe_round_robin(segs(11), 3);
        let arr = interleave_arrival_order(&queues);
        assert_eq!(arr.len(), 11);
        let mut seqs: Vec<u32> = arr.iter().map(|s| s.seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..11).collect::<Vec<_>>());
    }
}
