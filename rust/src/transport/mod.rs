//! Streaming delta transfer protocol (paper §5.2) and the runtime's
//! transport API (§4, §5.4).
//!
//! A delta checkpoint is treated as a stream of independently transmitted,
//! deterministically reassembled segments:
//!
//! * `segment`    — wire framing: (version, seq, total, payload, checksum);
//! * `stripe`     — round-robin assignment of segments to S parallel
//!                  streams, and per-stream serialization order;
//! * `reassembly` — order/duplication-tolerant reconstruction with
//!                  whole-artifact hash verification before commit;
//! * `relay`      — two-tier fanout: Trainer → regional seed Actor → peers,
//!                  forwarding segments on arrival (cut-through);
//! * `plan`       — the analytic timing of all of the above over `netsim`
//!                  links, plus the multi-region [`DistributionPlan`]:
//!                  a bandwidth-aware spanning tree (hub → regional relays
//!                  → actors) whose WAN legs stripe to each link's
//!                  bandwidth-delay product ([`stripe::stripes_for_link`]);
//! * `api`        — the [`Transport`] trait + [`HubEndpoint`] /
//!                  [`ActorEndpoint`] handles the pipelined executor
//!                  speaks (`rt::net::Msg` end to end), with the InProc
//!                  and Sim backends;
//! * `tcp`        — the loopback-socket backend: framed messages,
//!                  throttled multi-stream segment push, real
//!                  crash/partition failure injection.

pub mod api;
pub mod plan;
pub mod reassembly;
pub mod relay;
pub mod segment;
pub mod stripe;
pub mod tcp;

pub use api::{
    ActorEndpoint, ActorRunner, Closed, Event, HubEndpoint, InProcTransport, Polled, SimNetConfig,
    SimTransport, Transport,
};
pub use plan::{DistributionPlan, RegionTopo, RelayLeg, TransferPlan};
pub use reassembly::Reassembler;
pub use segment::{split_into_segments, Segment, DEFAULT_SEGMENT_BYTES, TOTAL_UNKNOWN};
pub use stripe::{stripe_round_robin, stripes_for_link, MAX_STRIPES};
pub use tcp::{KillMode, KillSpec, TcpConfig, TcpTransport};
