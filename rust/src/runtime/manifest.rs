//! Parse `artifacts/manifest.txt` (key=value lines emitted by aot.py) and
//! cross-check it against the rust-side model presets.

use crate::config::{self, ModelSpec};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Per-model artifact metadata.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub b_gen: usize,
    pub b_train: usize,
    pub param_count: u64,
    shapes: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    /// Load the section for `model` from the manifest file.
    pub fn load(path: &Path, model: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text, model)
    }

    pub fn parse(text: &str, model: &str) -> Result<Manifest> {
        // Sections are key=value runs separated by blank lines; find the
        // one whose `model=` matches.
        let mut sections: Vec<BTreeMap<String, String>> = vec![BTreeMap::new()];
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                sections.push(BTreeMap::new());
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                sections.last_mut().unwrap().insert(k.to_string(), v.to_string());
            }
        }
        let sec = sections
            .into_iter()
            .find(|s| s.get("model").map(|m| m == model).unwrap_or(false))
            .with_context(|| format!("model {model} not in manifest"))?;
        let get = |k: &str| -> Result<String> {
            sec.get(k).cloned().with_context(|| format!("manifest key {k}"))
        };
        let num = |k: &str| -> Result<usize> {
            get(k)?.parse().with_context(|| format!("manifest key {k} numeric"))
        };
        let mut shapes = Vec::new();
        for (k, v) in &sec {
            if let Some(name) = k.strip_prefix("shape.") {
                let dims: Result<Vec<usize>, _> =
                    v.split(',').map(|d| d.parse::<usize>()).collect();
                shapes.push((name.to_string(), dims.context("shape dims")?));
            }
        }
        let m = Manifest {
            model: model.to_string(),
            vocab: num("vocab")?,
            d_model: num("d_model")?,
            n_layers: num("n_layers")?,
            n_heads: num("n_heads")?,
            d_ff: num("d_ff")?,
            max_seq: num("max_seq")?,
            b_gen: num("b_gen")?,
            b_train: num("b_train")?,
            param_count: get("param_count")?.parse().context("param_count")?,
            shapes,
        };
        m.validate()?;
        Ok(m)
    }

    /// Tensor shapes in the fused layout order (the order the artifacts'
    /// parameters appear in).
    pub fn tensor_shapes(&self) -> Vec<Vec<usize>> {
        let spec = self.model_spec();
        spec.layout
            .tensors
            .iter()
            .map(|t| t.shape.clone())
            .collect()
    }

    /// The rust-side preset this manifest must agree with.
    pub fn model_spec(&self) -> ModelSpec {
        config::model(&self.model).expect("validated in parse()")
    }

    fn validate(&self) -> Result<()> {
        let Some(spec) = config::model(&self.model) else {
            bail!("manifest model {} has no rust preset", self.model)
        };
        if !spec.runnable {
            bail!("model {} is analytic-only", self.model);
        }
        let ok = spec.vocab == self.vocab
            && spec.d_model == self.d_model
            && spec.n_layers == self.n_layers
            && spec.n_heads == self.n_heads
            && spec.d_ff == self.d_ff
            && spec.max_seq == self.max_seq
            && spec.total_params() == self.param_count;
        if !ok {
            bail!(
                "manifest/preset mismatch for {}: python says V={} D={} L={} H={} F={} T={} P={}, \
                 rust says V={} D={} L={} H={} F={} T={} P={}",
                self.model,
                self.vocab, self.d_model, self.n_layers, self.n_heads, self.d_ff,
                self.max_seq, self.param_count,
                spec.vocab, spec.d_model, spec.n_layers, spec.n_heads, spec.d_ff,
                spec.max_seq, spec.total_params(),
            );
        }
        // Shapes from the manifest must match the layout tensor-for-tensor.
        for t in &spec.layout.tensors {
            let found = self.shapes.iter().find(|(n, _)| n == &t.name);
            match found {
                Some((_, dims)) if *dims == t.shape => {}
                other => bail!("shape mismatch for {}: {:?}", t.name, other),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
fingerprint=abc:sparrow-xs
model=sparrow-xs
vocab=256
d_model=64
n_layers=2
n_heads=4
d_ff=256
max_seq=64
b_gen=8
b_train=32
param_count=147776
shape.embed=256,64
shape.final_norm=64
shape.norms=2,2,64
shape.qkv_proj=2,64,192
shape.o_proj=2,64,64
shape.gate_up_proj=2,64,512
shape.down_proj=2,256,64
";

    #[test]
    fn parses_and_validates_against_preset() {
        let m = Manifest::parse(SAMPLE, "sparrow-xs").unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.b_gen, 8);
        assert_eq!(m.tensor_shapes()[0], vec![256, 64]);
        assert_eq!(
            m.param_count,
            config::model("sparrow-xs").unwrap().total_params()
        );
    }

    #[test]
    fn missing_model_is_error() {
        assert!(Manifest::parse(SAMPLE, "sparrow-s").is_err());
    }

    #[test]
    fn mismatched_dims_rejected() {
        let bad = SAMPLE.replace("d_model=64", "d_model=65");
        let err = Manifest::parse(&bad, "sparrow-xs").unwrap_err().to_string();
        assert!(err.contains("mismatch"), "{err}");
    }

    #[test]
    fn mismatched_shape_rejected() {
        let bad = SAMPLE.replace("shape.o_proj=2,64,64", "shape.o_proj=2,64,65");
        assert!(Manifest::parse(&bad, "sparrow-xs").is_err());
    }
}
