//! Trainer-side host parameter state: f32 master weights + Adam moments,
//! and quantization to the bf16 policy the actors serve.

use crate::delta::{ModelLayout, ParamSet};
use crate::util::{Bf16, Rng};

/// f32 master weights + Adam state (mirrors the train-step artifact I/O).
pub struct TrainState {
    pub masters: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// 1-based Adam timestep (incremented by Engines::train_step).
    pub step: u64,
}

impl TrainState {
    /// Transformer init matching python's `init_params`: Gaussian(0.02)
    /// weights, norm gains 1.0, zero moments.
    pub fn init(layout: &ModelLayout, rng: &mut Rng) -> TrainState {
        let masters: Vec<Vec<f32>> = layout
            .tensors
            .iter()
            .map(|t| {
                if t.name.contains("norm") {
                    vec![1.0f32; t.numel() as usize]
                } else {
                    (0..t.numel()).map(|_| rng.normal() as f32 * 0.02).collect()
                }
            })
            .collect();
        let zeros: Vec<Vec<f32>> =
            masters.iter().map(|t| vec![0.0f32; t.len()]).collect();
        TrainState { masters, m: zeros.clone(), v: zeros, step: 0 }
    }

    /// Quantize the masters into the bf16 policy snapshot actors run.
    pub fn to_policy(&self) -> ParamSet {
        ParamSet {
            tensors: self
                .masters
                .iter()
                .map(|t| t.iter().map(|&x| Bf16::from_f32(x)).collect())
                .collect(),
        }
    }

    pub fn total_params(&self) -> u64 {
        self.masters.iter().map(|t| t.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matches_layout_and_norms_are_one() {
        let layout = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(3);
        let st = TrainState::init(&layout, &mut rng);
        assert_eq!(st.total_params(), layout.total_params());
        let norms_id = layout.tensor_id("norms").unwrap();
        assert!(st.masters[norms_id].iter().all(|&x| x == 1.0));
        let fin = layout.tensor_id("final_norm").unwrap();
        assert!(st.masters[fin].iter().all(|&x| x == 1.0));
        let emb = layout.tensor_id("embed").unwrap();
        assert!(st.masters[emb].iter().any(|&x| x != 0.0));
        assert!(st.m.iter().flatten().all(|&x| x == 0.0));
    }

    #[test]
    fn policy_quantization_is_bf16_rounding() {
        let layout = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(4);
        let st = TrainState::init(&layout, &mut rng);
        let pol = st.to_policy();
        for (mt, pt) in st.masters.iter().zip(&pol.tensors) {
            for (&mf, &pb) in mt.iter().zip(pt) {
                assert_eq!(pb, Bf16::from_f32(mf));
            }
        }
    }
}
