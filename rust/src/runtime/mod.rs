//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the coordinator's request path — no Python anywhere.
//!
//! Pipeline (see /opt/xla-example/load_hlo and python/compile/aot.py):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::cpu().compile(..)` -> `execute(..)`. HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax>=0.5 protos).

pub mod manifest;
pub mod params;

pub use manifest::Manifest;
pub use params::TrainState;

use crate::delta::ParamSet;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Raw-byte views of typed slices (little-endian hosts; x86_64/aarch64).
fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
    }
}

fn lit_bf16(dims: &[usize], data: &[crate::util::Bf16]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::Bf16, dims, bytes_of(data))
        .map_err(|e| anyhow!("bf16 literal: {e:?}"))
}

fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes_of(data))
        .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

fn lit_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes_of(data))
        .map_err(|e| anyhow!("i32 literal: {e:?}"))
}

fn lit_scalar_f32(x: f32) -> Result<xla::Literal> {
    lit_f32(&[], &[x])
}

/// Read a literal's contents as f32 (converting if needed — bf16 -> f32 is
/// exact, so this is lossless for policy outputs).
fn read_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    let conv = lit
        .convert(xla::PrimitiveType::F32)
        .map_err(|e| anyhow!("convert: {e:?}"))?;
    conv.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// One compiled artifact.
struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Artifact {
    fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: readback: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("{}: untuple: {e:?}", self.name))
    }
}

/// The runtime for one model: PJRT client + compiled entry points.
pub struct Engines {
    pub manifest: Manifest,
    policy_fwd: Artifact,
    train_step: Artifact,
    delta_diff: Option<Artifact>,
    #[allow(dead_code)]
    client: xla::PjRtClient,
}

impl Engines {
    /// Compile the model's artifacts from `dir` on the CPU PJRT client.
    pub fn load(dir: &Path, model: &str) -> Result<Engines> {
        let manifest = Manifest::load(&dir.join("manifest.txt"), model)
            .with_context(|| format!("manifest for {model}"))?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let load = |kind: &str| -> Result<Artifact> {
            let path: PathBuf = dir.join(format!("{model}_{kind}.hlo.txt"));
            if !path.exists() {
                bail!("missing artifact {} (run `make artifacts`)", path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf-8 path")?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            Ok(Artifact { exe, name: format!("{model}_{kind}") })
        };
        let policy_fwd = load("policy_fwd")?;
        let train_step = load("train_step")?;
        let delta_diff = load("delta_diff").ok();
        Ok(Engines { manifest, policy_fwd, train_step, delta_diff, client })
    }

    /// Rollout forward: bf16 policy + tokens [b_gen * max_seq] (row-major)
    /// -> logits [b_gen * max_seq * vocab] f32.
    pub fn policy_logits(&self, policy: &ParamSet, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let (b, t) = (m.b_gen, m.max_seq);
        if tokens.len() != b * t {
            bail!("tokens len {} != b_gen*max_seq {}", tokens.len(), b * t);
        }
        let mut inputs = Vec::with_capacity(8);
        for (shape, data) in m.tensor_shapes().iter().zip(&policy.tensors) {
            inputs.push(lit_bf16(shape, data)?);
        }
        inputs.push(lit_i32(&[b, t], tokens)?);
        let out = self.policy_fwd.run(&inputs)?;
        read_f32(&out[0])
    }

    /// One optimizer step in place on `state`; returns the loss.
    /// `tokens`/`mask` are `[b_train * max_seq]`; `adv` is `[b_train]`.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        mask: &[f32],
        adv: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let m = &self.manifest;
        let (b, t) = (m.b_train, m.max_seq);
        if tokens.len() != b * t || mask.len() != b * t || adv.len() != b {
            bail!("train batch shape mismatch");
        }
        let shapes = m.tensor_shapes();
        let mut inputs = Vec::with_capacity(26);
        for (shape, data) in shapes.iter().zip(&state.masters) {
            inputs.push(lit_f32(shape, data)?);
        }
        for (shape, data) in shapes.iter().zip(&state.m) {
            inputs.push(lit_f32(shape, data)?);
        }
        for (shape, data) in shapes.iter().zip(&state.v) {
            inputs.push(lit_f32(shape, data)?);
        }
        inputs.push(lit_i32(&[b, t], tokens)?);
        inputs.push(lit_f32(&[b, t], mask)?);
        inputs.push(lit_f32(&[b], adv)?);
        inputs.push(lit_scalar_f32(lr)?);
        state.step += 1;
        inputs.push(lit_scalar_f32(state.step as f32)?);
        let out = self.train_step.run(&inputs)?;
        if out.len() != 22 {
            bail!("train_step returned {} outputs, want 22", out.len());
        }
        for (dst, lit) in state.masters.iter_mut().zip(&out[0..7]) {
            *dst = read_f32(lit)?;
        }
        for (dst, lit) in state.m.iter_mut().zip(&out[7..14]) {
            *dst = read_f32(lit)?;
        }
        for (dst, lit) in state.v.iter_mut().zip(&out[14..21]) {
            *dst = read_f32(lit)?;
        }
        let loss = read_f32(&out[21])?;
        Ok(loss[0])
    }

    /// Pallas delta-diff kernel: change mask + nnz between two policies.
    pub fn delta_diff(&self, old: &ParamSet, new: &ParamSet) -> Result<(Vec<u8>, i64)> {
        let art = self
            .delta_diff
            .as_ref()
            .context("delta_diff artifact not loaded")?;
        let shapes = self.manifest.tensor_shapes();
        let mut inputs = Vec::with_capacity(14);
        for (shape, data) in shapes.iter().zip(&old.tensors) {
            inputs.push(lit_bf16(shape, data)?);
        }
        for (shape, data) in shapes.iter().zip(&new.tensors) {
            inputs.push(lit_bf16(shape, data)?);
        }
        let out = art.run(&inputs)?;
        let mask_f = read_f32(&out[0])?;
        let nnz = read_f32(&out[1])?[0] as i64;
        Ok((mask_f.into_iter().map(|x| x as u8).collect(), nnz))
    }

    pub fn has_delta_diff(&self) -> bool {
        self.delta_diff.is_some()
    }
}

/// Default artifacts directory: $SPARROW_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SPARROW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_of_views_little_endian() {
        let xs = [1.0f32, -2.0];
        let b = bytes_of(&xs);
        assert_eq!(b.len(), 8);
        assert_eq!(&b[0..4], &1.0f32.to_le_bytes());
        assert_eq!(&b[4..8], &(-2.0f32).to_le_bytes());
        let bf = [crate::util::Bf16::from_f32(1.0)];
        assert_eq!(bytes_of(&bf), &0x3F80u16.to_le_bytes());
    }
}
