//! RL advantage estimators: GRPO, RLOO, and OPO (paper Table 4).
//!
//! SparrowRL "requires no modifications to the underlying RL algorithms":
//! the train-step artifact consumes per-sequence advantages, and these
//! estimators — the only place the three algorithms differ for our
//! purposes — run in the coordinator over each prompt's rollout group.

/// Which estimator turns group rewards into advantages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Group-normalized: (r - mean) / (std + eps)   (DeepSeekMath).
    Grpo,
    /// Leave-one-out baseline: r_i - mean(r_{j != i})   [Ahmadian et al.].
    Rloo,
    /// Optimal (length-weighted) reward baseline: r_i - sum(l r)/sum(l).
    Opo,
}

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Grpo => "GRPO",
            Algorithm::Rloo => "RLOO",
            Algorithm::Opo => "OPO",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "grpo" => Some(Algorithm::Grpo),
            "rloo" => Some(Algorithm::Rloo),
            "opo" => Some(Algorithm::Opo),
            _ => None,
        }
    }

    pub fn all() -> [Algorithm; 3] {
        [Algorithm::Grpo, Algorithm::Rloo, Algorithm::Opo]
    }

    /// Advantages for one rollout group. `lengths` are generated-token
    /// counts (OPO's baseline weights; ignored by GRPO/RLOO).
    pub fn advantages(self, rewards: &[f32], lengths: &[usize]) -> Vec<f32> {
        assert_eq!(rewards.len(), lengths.len());
        let n = rewards.len();
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            // Degenerate group: no baseline is estimable.
            return vec![0.0];
        }
        let mean = rewards.iter().sum::<f32>() / n as f32;
        match self {
            Algorithm::Grpo => {
                let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>()
                    / n as f32;
                let std = var.sqrt();
                let denom = std + 1e-4;
                rewards.iter().map(|r| (r - mean) / denom).collect()
            }
            Algorithm::Rloo => {
                let sum: f32 = rewards.iter().sum();
                rewards
                    .iter()
                    .map(|&r| {
                        let loo_mean = (sum - r) / (n as f32 - 1.0);
                        r - loo_mean
                    })
                    .collect()
            }
            Algorithm::Opo => {
                let wsum: f32 = lengths.iter().map(|&l| l as f32).sum();
                if wsum <= 0.0 {
                    return rewards.iter().map(|&r| r - mean).collect();
                }
                let baseline = rewards
                    .iter()
                    .zip(lengths)
                    .map(|(&r, &l)| r * l as f32)
                    .sum::<f32>()
                    / wsum;
                rewards.iter().map(|&r| r - baseline).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn grpo_normalizes_to_zero_mean_unit_scale() {
        let r = [1.0, 0.0, 1.0, 0.0];
        let adv = Algorithm::Grpo.advantages(&r, &[4; 4]);
        let mean: f32 = adv.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!(adv[0] > 0.9 && adv[1] < -0.9);
    }

    #[test]
    fn grpo_uniform_rewards_give_zero_advantage() {
        let adv = Algorithm::Grpo.advantages(&[0.5; 8], &[3; 8]);
        assert!(adv.iter().all(|a| a.abs() < 1e-6));
    }

    #[test]
    fn rloo_matches_hand_computation() {
        let r = [1.0, 0.0, 0.5];
        // baselines: (0+0.5)/2=0.25, (1+0.5)/2=0.75, (1+0)/2=0.5
        close(
            &Algorithm::Rloo.advantages(&r, &[1; 3]),
            &[0.75, -0.75, 0.0],
        );
    }

    #[test]
    fn rloo_advantages_sum_to_zero() {
        let r = [0.3, 0.9, 0.1, 0.6, 1.0];
        let adv = Algorithm::Rloo.advantages(&r, &[2; 5]);
        let s: f32 = adv.iter().sum();
        assert!(s.abs() < 1e-5);
    }

    #[test]
    fn opo_length_weighted_baseline() {
        let r = [1.0, 0.0];
        let l = [3usize, 1];
        // baseline = (3*1 + 1*0)/4 = 0.75
        close(&Algorithm::Opo.advantages(&r, &l), &[0.25, -0.75]);
    }

    #[test]
    fn opo_equal_lengths_reduces_to_mean_baseline() {
        let r = [1.0, 0.0, 0.5, 0.5];
        let opo = Algorithm::Opo.advantages(&r, &[7; 4]);
        let mean = 0.5;
        let want: Vec<f32> = r.iter().map(|x| x - mean).collect();
        close(&opo, &want);
    }

    #[test]
    fn singleton_group_yields_zero() {
        for alg in Algorithm::all() {
            assert_eq!(alg.advantages(&[0.7], &[4]), vec![0.0]);
        }
    }

    #[test]
    fn empty_group_yields_empty() {
        for alg in Algorithm::all() {
            assert!(alg.advantages(&[], &[]).is_empty());
        }
    }
}
