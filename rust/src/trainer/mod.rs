//! Trainer Hub: policy optimization, advantage estimation, and the delta
//! extraction pipeline (paper §4's "Trainer Hub" tier).
//!
//! The compute itself (fwd/bwd/Adam) lives in the AOT train-step artifact
//! executed through `runtime/`; this module owns everything around it:
//! rollout grouping, the GRPO/RLOO/OPO estimators, and turning consecutive
//! bf16 policy snapshots into sealed delta checkpoints.

pub mod algorithms;

pub use algorithms::Algorithm;

use crate::delta::stream::{DeltaStreamEncoder, StreamConfig, StreamStats};
use crate::delta::{extract_delta, ApplyMode, DeltaCheckpoint, ModelLayout, ParamSet};
use crate::transport::Segment;

/// One completed rollout returned by an actor.
#[derive(Clone, Debug)]
pub struct Rollout {
    pub prompt_id: u64,
    pub actor: u32,
    /// Policy version the rollout was generated on.
    pub version: u64,
    pub prompt_tokens: Vec<i32>,
    pub generated_tokens: Vec<i32>,
    pub reward: f32,
}

/// Group rollouts by prompt and compute per-sequence advantages
/// (GRPO-family algorithms operate on per-prompt groups of size G).
pub fn group_advantages(rollouts: &[Rollout], alg: Algorithm) -> Vec<f32> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, r) in rollouts.iter().enumerate() {
        groups.entry(r.prompt_id).or_default().push(i);
    }
    let mut adv = vec![0.0f32; rollouts.len()];
    for idx in groups.values() {
        let rewards: Vec<f32> = idx.iter().map(|&i| rollouts[i].reward).collect();
        let lengths: Vec<usize> = idx
            .iter()
            .map(|&i| rollouts[i].generated_tokens.len())
            .collect();
        for (k, &i) in idx.iter().enumerate() {
            adv[i] = alg.advantages(&rewards, &lengths)[k];
        }
    }
    adv
}

/// Snapshot-diff the old/new bf16 policies into a sealed, versioned delta
/// checkpoint (the paper's step-(4): encode + store). Legacy three-pass
/// path, kept for comparison experiments; the runtime's hot path is
/// [`stream_checkpoint`].
pub fn extract_checkpoint(
    layout: &ModelLayout,
    old_policy: &ParamSet,
    new_policy: &ParamSet,
    base_version: u64,
    version: u64,
) -> DeltaCheckpoint {
    let delta = extract_delta(layout, old_policy, new_policy, base_version, version, ApplyMode::Assign);
    DeltaCheckpoint::seal(&delta)
}

/// Fused streaming path (paper §5.2): diff, encode, and segment the new
/// policy in one pass, handing each wire-ready segment to `sink` — *by
/// value*, so a single-destination sink forwards without copying — as soon
/// as it closes; transmission overlaps extraction. The sealed checkpoint
/// artifact (for the Checkpoint Store) is assembled from the same bytes,
/// so no second encode pass runs. Byte-identical to
/// [`extract_checkpoint`]'s artifact.
pub fn stream_checkpoint<F: FnMut(Segment)>(
    layout: &ModelLayout,
    old_policy: &ParamSet,
    new_policy: &ParamSet,
    base_version: u64,
    version: u64,
    segment_bytes: usize,
    mut sink: F,
) -> (DeltaCheckpoint, StreamStats) {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let enc = DeltaStreamEncoder::new(
        layout,
        base_version,
        version,
        ApplyMode::Assign,
        StreamConfig { segment_bytes, ..Default::default() },
    );
    let mut bytes = Vec::new();
    let stats = enc.encode_parallel(old_policy, new_policy, threads, |seg| {
        bytes.extend_from_slice(&seg.payload);
        sink(seg);
    });
    let ckpt = DeltaCheckpoint { version, base_version, bytes, hash: stats.hash };
    (ckpt, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rollout(prompt: u64, reward: f32, len: usize) -> Rollout {
        Rollout {
            prompt_id: prompt,
            actor: 0,
            version: 1,
            prompt_tokens: vec![1],
            generated_tokens: vec![5; len],
            reward,
        }
    }

    #[test]
    fn advantages_are_computed_per_group() {
        let rs = vec![
            rollout(1, 1.0, 4),
            rollout(1, 0.0, 4),
            rollout(2, 0.5, 4),
            rollout(2, 0.5, 4),
        ];
        let adv = group_advantages(&rs, Algorithm::Grpo);
        // Group 1 has spread; group 2 is uniform -> zero advantage.
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
        assert!(adv[2].abs() < 1e-6 && adv[3].abs() < 1e-6);
    }

    #[test]
    fn interleaved_groups_map_back_correctly() {
        let rs = vec![
            rollout(9, 1.0, 2),
            rollout(7, 0.0, 2),
            rollout(9, 0.0, 2),
            rollout(7, 1.0, 2),
        ];
        let adv = group_advantages(&rs, Algorithm::Rloo);
        assert!(adv[0] > 0.0 && adv[2] < 0.0, "group 9 order kept");
        assert!(adv[1] < 0.0 && adv[3] > 0.0, "group 7 order kept");
    }

    #[test]
    fn stream_checkpoint_matches_legacy_artifact() {
        use crate::util::{Bf16, Rng};
        let layout = ModelLayout::transformer("t", 128, 32, 2, 64);
        let mut rng = Rng::new(5);
        let old = ParamSet::random(&layout, 0.02, &mut rng);
        let mut new = old.clone();
        for t in &mut new.tensors {
            for _ in 0..6 {
                let i = rng.range(0, t.len());
                t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0020);
            }
        }
        let legacy = extract_checkpoint(&layout, &old, &new, 2, 3);
        let mut seg_bytes_seen = 0usize;
        let (streamed, stats) =
            stream_checkpoint(&layout, &old, &new, 2, 3, 256, |seg| {
                seg_bytes_seen += seg.payload.len();
            });
        assert_eq!(streamed.bytes, legacy.bytes, "artifacts byte-identical");
        assert_eq!(streamed.hash, legacy.hash);
        assert_eq!(seg_bytes_seen, legacy.bytes.len());
        assert_eq!(stats.payload_bytes as usize, legacy.bytes.len());
        assert!(stats.nnz > 0);
    }

    #[test]
    fn extract_checkpoint_round_trips() {
        use crate::util::{Bf16, Rng};
        let layout = ModelLayout::transformer("t", 64, 16, 2, 32);
        let mut rng = Rng::new(1);
        let old = ParamSet::random(&layout, 0.02, &mut rng);
        let mut new = old.clone();
        new.tensors[0][3] = Bf16::from_bits(new.tensors[0][3].to_bits() ^ 1);
        let ckpt = extract_checkpoint(&layout, &old, &new, 4, 5);
        assert_eq!(ckpt.version, 5);
        assert_eq!(ckpt.base_version, 4);
        let d = ckpt.open().unwrap();
        assert_eq!(d.nnz(), 1);
    }
}
