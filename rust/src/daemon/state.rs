//! Daemon-wide shared state: the run table, the global alert list, and
//! the **admission controller** that arbitrates the shared actor pool
//! across sessions.
//!
//! Arbitration rules (docs/ARCHITECTURE.md §2f):
//!
//! * The daemon owns a fixed synthetic fleet of [`DaemonConfig::actor_pool`]
//!   actor slots and at most [`DaemonConfig::max_sessions`] concurrently
//!   *running* sessions.
//! * A submitted run declares its actor need up front (its `RunPlan`'s
//!   `n_actors`). A run needing more slots than the whole pool is
//!   rejected at submission (422) — it could never start.
//! * Otherwise the run is **queued, never rejected**: the FIFO scheduler
//!   starts it as soon as the head of the queue fits in both the free
//!   slot count and the session cap. Scheduling is strictly in
//!   submission order (no overtaking), so a big run cannot be starved by
//!   a stream of small ones.
//! * Slots are released when the drain thread observes the session
//!   terminal, which re-runs the scheduler.
//!
//! Lock order: the one [`Inner`] mutex here is taken *before* any run's
//! log lock, never after (registry drain threads call back into
//! [`DaemonState::push_alert`] / [`DaemonState::on_run_terminal`] only
//! with their run lock released).

use super::alerts::{Alert, AlertRules};
use super::registry::{RunEntry, RunMeta, RunPhase};
use crate::bench::scenario::BenchModel;
use crate::session::RunPlan;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Daemon configuration (CLI: `sparrowrl serve`).
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, CI smoke).
    pub addr: String,
    /// Max concurrently *running* sessions.
    pub max_sessions: usize,
    /// Synthetic actor slots shared by all running sessions.
    pub actor_pool: usize,
    /// Max queued-or-running runs retained in the table; beyond this,
    /// submissions get 503 (backpressure, not memory growth).
    pub max_runs: usize,
    /// Max concurrent HTTP connections (excess get 503).
    pub max_connections: usize,
    /// Alert thresholds applied to every hosted run.
    pub rules: AlertRules,
    /// Model registry directory served under `/models` and consulted by
    /// `POST /runs/{id}/swap`. `None` disables both (409 `no_registry`).
    pub registry: Option<std::path::PathBuf>,
}

impl Default for DaemonConfig {
    fn default() -> DaemonConfig {
        DaemonConfig {
            addr: "127.0.0.1:7770".to_string(),
            max_sessions: 4,
            actor_pool: 16,
            max_runs: 256,
            max_connections: 64,
            rules: AlertRules::default(),
            registry: None,
        }
    }
}

/// Why a submission was not admitted.
#[derive(Debug, PartialEq)]
pub enum SubmitError {
    /// The run wants more actors than the whole pool — it can never be
    /// scheduled, so queueing it would be a lie. HTTP 422.
    ExceedsActorPool { wanted: usize, pool: usize },
    /// The run table is full. HTTP 503 (retry later).
    TableFull { max_runs: usize },
}

impl SubmitError {
    pub fn kind(&self) -> &'static str {
        match self {
            SubmitError::ExceedsActorPool { .. } => "ExceedsActorPool",
            SubmitError::TableFull { .. } => "TableFull",
        }
    }

    pub fn message(&self) -> String {
        match self {
            SubmitError::ExceedsActorPool { wanted, pool } => format!(
                "run wants {wanted} actors but the daemon's shared pool has only {pool} slots"
            ),
            SubmitError::TableFull { max_runs } => {
                format!("run table is at its {max_runs}-run capacity; retry later")
            }
        }
    }
}

struct Inner {
    next_id: u64,
    /// Submission order — also the scheduling order.
    runs: Vec<RunEntry>,
    alerts: Vec<Alert>,
    drains: Vec<JoinHandle<()>>,
}

/// The shared daemon state every connection thread and drain thread
/// hangs off.
pub struct DaemonState {
    pub cfg: DaemonConfig,
    inner: Mutex<Inner>,
    shutdown: AtomicBool,
}

impl DaemonState {
    pub fn new(cfg: DaemonConfig) -> DaemonState {
        DaemonState {
            cfg,
            inner: Mutex::new(Inner {
                next_id: 1,
                runs: Vec::new(),
                alerts: Vec::new(),
                drains: Vec::new(),
            }),
            shutdown: AtomicBool::new(false),
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    /// Admit a run: allocate an id, queue it, then run the scheduler.
    /// `n_actors`/`regions` must describe the built plan.
    pub fn submit(
        self: &Arc<Self>,
        plan: RunPlan,
        model: BenchModel,
        transport: String,
        seed: u64,
    ) -> Result<RunEntry, SubmitError> {
        let n_actors = plan.config().n_actors;
        if n_actors > self.cfg.actor_pool {
            return Err(SubmitError::ExceedsActorPool {
                wanted: n_actors,
                pool: self.cfg.actor_pool,
            });
        }
        let regions = plan
            .config()
            .distribution
            .as_ref()
            .and_then(|d| d.region_of.iter().max().map(|m| m + 1))
            .unwrap_or(1);
        let entry = {
            let mut inner = self.lock();
            let active = inner
                .runs
                .iter()
                .filter(|r| !r.phase().is_terminal())
                .count();
            if active >= self.cfg.max_runs {
                return Err(SubmitError::TableFull { max_runs: self.cfg.max_runs });
            }
            let id = format!("r{}", inner.next_id);
            inner.next_id += 1;
            let meta = RunMeta {
                id,
                model: model.name.to_string(),
                steps: plan.config().steps,
                seed,
                n_actors,
                regions,
                transport,
                mode: match plan.mode() {
                    crate::rt::ExecMode::Pipelined => "pipelined",
                    crate::rt::ExecMode::Sequential => "sequential",
                },
            };
            let entry = RunEntry::queued(meta, plan, model, self.cfg.rules.clone());
            inner.runs.push(entry.clone());
            entry
        };
        self.schedule();
        Ok(entry)
    }

    /// FIFO scheduler: start queued runs, in submission order, while the
    /// head fits in the free actor slots and the session cap. Stops at
    /// the first run that does not fit (no overtaking).
    pub fn schedule(self: &Arc<Self>) {
        if self.is_shutdown() {
            return;
        }
        let mut inner = self.lock();
        loop {
            let mut used_slots = 0usize;
            let mut running = 0usize;
            let mut head: Option<RunEntry> = None;
            for entry in &inner.runs {
                match entry.phase() {
                    RunPhase::Running => {
                        running += 1;
                        used_slots += entry.meta.n_actors;
                    }
                    RunPhase::Queued => {
                        if head.is_none() {
                            head = Some(entry.clone());
                        }
                    }
                    _ => {}
                }
            }
            let Some(entry) = head else { break };
            if running >= self.cfg.max_sessions
                || used_slots + entry.meta.n_actors > self.cfg.actor_pool
            {
                break;
            }
            let state = self.clone();
            let on_alert = move |alert: Alert| state.push_alert(alert);
            let state = self.clone();
            let on_terminal = move |id: &str| {
                let _ = id;
                state.on_run_terminal();
            };
            match entry.start(on_alert, on_terminal) {
                Ok(handle) => inner.drains.push(handle),
                // Startup failure: the entry is already `Failed`; keep
                // scheduling — the next queued run may still fit.
                Err(_) => continue,
            }
        }
    }

    /// Drain-thread callback once a run reached a terminal phase: its
    /// slots are free, so the queue head may now fit.
    pub fn on_run_terminal(self: &Arc<Self>) {
        self.schedule();
    }

    /// Record a fired alert in the daemon-wide list.
    pub fn push_alert(&self, alert: Alert) {
        self.lock().alerts.push(alert);
    }

    pub fn find(&self, id: &str) -> Option<RunEntry> {
        self.lock().runs.iter().find(|r| r.meta.id == id).cloned()
    }

    /// `GET /runs` body.
    pub fn list_json(&self) -> Json {
        let rows: Vec<Json> = self.lock().runs.iter().map(|r| r.row()).collect();
        Json::obj().set("runs", rows)
    }

    /// `GET /alerts` body.
    pub fn alerts_json(&self) -> Json {
        let alerts: Vec<Json> = self.lock().alerts.iter().map(|a| a.to_json()).collect();
        Json::obj().set("alerts", alerts)
    }

    /// Pool occupancy snapshot (index page + tests).
    pub fn pool_json(&self) -> Json {
        let inner = self.lock();
        let mut used = 0usize;
        let mut running = 0usize;
        let mut queued = 0usize;
        for entry in &inner.runs {
            match entry.phase() {
                RunPhase::Running => {
                    running += 1;
                    used += entry.meta.n_actors;
                }
                RunPhase::Queued => queued += 1,
                _ => {}
            }
        }
        Json::obj()
            .set("actor_pool", self.cfg.actor_pool)
            .set("actors_in_use", used)
            .set("max_sessions", self.cfg.max_sessions)
            .set("running", running)
            .set("queued", queued)
    }

    /// Stop everything: refuse new scheduling, abort all live runs, and
    /// join every drain thread (which joins the sessions beneath).
    pub fn shutdown_all(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let drains = {
            let mut inner = self.lock();
            for entry in &inner.runs {
                entry.request_abort();
            }
            std::mem::take(&mut inner.drains)
        };
        for handle in drains {
            let _ = handle.join();
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("daemon state poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::bench_model;
    use crate::session::RunSpec;

    fn state(max_sessions: usize, actor_pool: usize) -> Arc<DaemonState> {
        Arc::new(DaemonState::new(DaemonConfig {
            max_sessions,
            actor_pool,
            ..DaemonConfig::default()
        }))
    }

    fn plan(actors: usize, steps: u64) -> RunPlan {
        RunSpec::synthetic()
            .actors(actors)
            .steps(steps)
            .deterministic()
            .build()
            .unwrap()
    }

    #[test]
    fn oversized_run_is_rejected_at_submission() {
        let s = state(4, 4);
        let err = s
            .submit(plan(5, 2), bench_model("syn-xs").unwrap(), "inproc".into(), 0)
            .unwrap_err();
        assert_eq!(err.kind(), "ExceedsActorPool");
        assert!(err.message().contains("5 actors"));
    }

    #[test]
    fn submissions_get_sequential_ids_and_appear_in_the_list() {
        // Pool of zero sessions: everything queues, nothing starts — the
        // admission bookkeeping is observable without running sessions.
        let s = state(0, 8);
        let a = s
            .submit(plan(2, 2), bench_model("syn-xs").unwrap(), "inproc".into(), 1)
            .unwrap();
        let b = s
            .submit(plan(2, 2), bench_model("syn-xs").unwrap(), "inproc".into(), 2)
            .unwrap();
        assert_eq!(a.meta.id, "r1");
        assert_eq!(b.meta.id, "r2");
        assert_eq!(a.phase(), RunPhase::Queued);
        let list = s.list_json();
        assert_eq!(list.get("runs").and_then(Json::as_arr).unwrap().len(), 2);
        let pool = s.pool_json();
        assert_eq!(pool.get("queued").and_then(Json::as_u64), Some(2));
        assert_eq!(pool.get("actors_in_use").and_then(Json::as_u64), Some(0));
        s.shutdown_all();
    }

    #[test]
    fn scheduler_is_fifo_without_overtaking() {
        // One session slot, zero-size... instead: cap sessions at 0 so
        // nothing starts, then verify find() and abort-while-queued
        // frees the table slot accounting.
        let s = state(0, 4);
        let a = s
            .submit(plan(4, 2), bench_model("syn-xs").unwrap(), "inproc".into(), 1)
            .unwrap();
        assert!(s.find("r1").is_some());
        assert!(s.find("r9").is_none());
        assert!(a.request_abort());
        assert_eq!(a.phase(), RunPhase::Aborted);
        s.shutdown_all();
    }
}
