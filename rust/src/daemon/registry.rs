//! The daemon's run table: one [`RunEntry`] per submitted run, each
//! owning its [`Session`] through a dedicated **drain thread**.
//!
//! Threading model (see docs/ARCHITECTURE.md §2f):
//!
//! * The session runtime thread emits [`Event`]s into its channel, as
//!   always — the daemon never touches it directly.
//! * One drain thread per running session loops `session.recv()` and
//!   folds every event, under the run's log lock, into three sinks at
//!   once: the bounded SSE frame log (what `GET /runs/{id}/events`
//!   replays and tails), the live [`Analytics`], and the [`AlertEngine`].
//! * HTTP connection threads only ever *read* the log under the same
//!   lock (snapshots) or wait on its condvar (SSE tails). They never
//!   block on the session.
//!
//! Lock order: the daemon-wide state lock may be taken **before** a run
//! log lock, never after. The drain thread therefore collects global
//! alerts and the terminal notification while holding the run lock, but
//! delivers them to [`DaemonState`] only after releasing it.

use super::alerts::{Alert, AlertEngine, AlertRules};
use super::analytics::Analytics;
use crate::bench::scenario::{bench_model, BenchModel};
use crate::rt::SyntheticCompute;
use crate::session::{Event, RunPlan, Session, SessionProbe, ABORT_MSG};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Emulated compute latencies for daemon-hosted synthetic runs — the
/// same figures the bench harness pins (`bench::runner`), so per-step
/// wall time and overlap gauges are comparable across surfaces.
pub const TRAIN_DELAY: Duration = Duration::from_millis(4);
pub const GEN_DELAY: Duration = Duration::from_millis(3);

/// Cap on retained SSE frames per run. A tail that falls further behind
/// than this sees a `gap` comment and resumes from the oldest retained
/// frame — bounded memory beats unbounded replay.
pub const MAX_FRAMES: usize = 65_536;

/// Where a run is in the daemon's lifecycle. `Queued` precedes any
/// session existing (admission control held it back); the terminal
/// states mirror [`SessionStatus`](crate::session::SessionStatus).
#[derive(Clone, Debug, PartialEq)]
pub enum RunPhase {
    Queued,
    Running,
    Finished,
    Aborted,
    Failed(String),
}

impl RunPhase {
    pub fn name(&self) -> &'static str {
        match self {
            RunPhase::Queued => "queued",
            RunPhase::Running => "running",
            RunPhase::Finished => "finished",
            RunPhase::Aborted => "aborted",
            RunPhase::Failed(_) => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, RunPhase::Finished | RunPhase::Aborted | RunPhase::Failed(_))
    }
}

/// Immutable submission facts (safe to read without the log lock).
#[derive(Clone, Debug)]
pub struct RunMeta {
    pub id: String,
    pub model: String,
    pub steps: u64,
    pub seed: u64,
    pub n_actors: usize,
    pub regions: usize,
    pub transport: String,
    pub mode: &'static str,
}

/// One rendered SSE frame: `id: seq` / `event: <name>` / `data: <json>`.
#[derive(Clone, Debug)]
pub struct SseFrame {
    pub seq: u64,
    pub event: &'static str,
    pub data: String,
}

/// Mutable per-run state, guarded by [`RunShared::log`].
pub(crate) struct RunLog {
    pub phase: RunPhase,
    /// The plan a queued run will start from; taken by the scheduler.
    pub pending: Option<(RunPlan, BenchModel)>,
    /// Probe into the live session (None while queued / after terminal
    /// bookkeeping no longer needs it).
    pub probe: Option<SessionProbe>,
    pub analytics: Analytics,
    pub alert_engine: AlertEngine,
    /// This run's fired alerts (the global list lives in `DaemonState`).
    pub alerts: Vec<Alert>,
    /// Hex SHA-256 of the final committed policy, once finished.
    pub final_checksum: Option<String>,
    frames: VecDeque<SseFrame>,
    next_seq: u64,
}

impl RunLog {
    fn push_frame(&mut self, event: &'static str, data: Json) {
        if self.frames.len() >= MAX_FRAMES {
            self.frames.pop_front();
        }
        self.frames.push_back(SseFrame {
            seq: self.next_seq,
            event,
            data: data.to_string(),
        });
        self.next_seq += 1;
    }

    /// Frames with `seq >= from`; `gap` reports whether older frames
    /// were already evicted (the subscriber missed some).
    pub(crate) fn frames_from(&self, from: u64) -> (Vec<SseFrame>, bool) {
        let oldest = self.frames.front().map(|f| f.seq).unwrap_or(self.next_seq);
        let gap = from < oldest;
        (self.frames.iter().filter(|f| f.seq >= from).cloned().collect(), gap)
    }

    fn status_json(&self, meta: &RunMeta) -> Json {
        let mut j = Json::obj()
            .set("run", meta.id.as_str())
            .set("status", self.phase.name());
        if let RunPhase::Failed(reason) = &self.phase {
            j = j.set("reason", reason.as_str());
        }
        if let Some(sum) = &self.final_checksum {
            j = j.set("final_checksum", sum.as_str());
        }
        j
    }
}

/// The shared half of a run: its guarded log plus the condvar SSE
/// subscribers park on.
pub(crate) struct RunShared {
    pub log: Mutex<RunLog>,
    pub cv: Condvar,
}

impl RunShared {
    pub(crate) fn lock(&self) -> MutexGuard<'_, RunLog> {
        self.log.lock().expect("run log poisoned")
    }

    pub(crate) fn notify(&self) {
        self.cv.notify_all();
    }
}

/// One run in the table: immutable meta + shared mutable log.
#[derive(Clone)]
pub struct RunEntry {
    pub meta: Arc<RunMeta>,
    pub(crate) shared: Arc<RunShared>,
}

impl RunEntry {
    /// Admit a new run in `Queued` phase, holding its plan until the
    /// scheduler grants actor-pool slots.
    pub(crate) fn queued(
        meta: RunMeta,
        plan: RunPlan,
        model: BenchModel,
        rules: AlertRules,
    ) -> RunEntry {
        let analytics = Analytics::new(meta.n_actors, meta.regions);
        let meta = Arc::new(meta);
        let mut log = RunLog {
            phase: RunPhase::Queued,
            pending: Some((plan, model)),
            probe: None,
            analytics,
            alert_engine: AlertEngine::new(rules),
            alerts: Vec::new(),
            final_checksum: None,
            frames: VecDeque::new(),
            next_seq: 0,
        };
        log.push_frame("status", log.status_json(&meta));
        RunEntry {
            meta,
            shared: Arc::new(RunShared { log: Mutex::new(log), cv: Condvar::new() }),
        }
    }

    /// Full JSON snapshot for `GET /runs/{id}` (and list rows).
    pub fn snapshot(&self) -> Json {
        let log = self.shared.lock();
        let mut j = Json::obj()
            .set("id", self.meta.id.as_str())
            .set("model", self.meta.model.as_str())
            .set("status", log.phase.name())
            .set("steps_requested", self.meta.steps)
            .set("seed", self.meta.seed)
            .set("actors", self.meta.n_actors)
            .set("regions", self.meta.regions)
            .set("transport", self.meta.transport.as_str())
            .set("mode", self.meta.mode)
            .set("alerts", log.alerts.len())
            .set("analytics", log.analytics.to_json());
        if let RunPhase::Failed(reason) = &log.phase {
            j = j.set("reason", reason.as_str());
        }
        if let Some(sum) = &log.final_checksum {
            j = j.set("final_checksum", sum.as_str());
        }
        j
    }

    /// Compact row for `GET /runs`.
    pub fn row(&self) -> Json {
        let log = self.shared.lock();
        Json::obj()
            .set("id", self.meta.id.as_str())
            .set("model", self.meta.model.as_str())
            .set("status", log.phase.name())
            .set("step", log.analytics.steps)
            .set("actors", self.meta.n_actors)
    }

    /// Current phase (brief lock).
    pub fn phase(&self) -> RunPhase {
        self.shared.lock().phase.clone()
    }

    /// Abort a run: a queued run terminates immediately (its slots were
    /// never granted); a running one gets the cooperative cancel via its
    /// probe and terminates when the drain thread observes it. Returns
    /// false if the run was already terminal.
    pub(crate) fn request_abort(&self) -> bool {
        let mut log = self.shared.lock();
        match log.phase {
            RunPhase::Queued => {
                log.pending = None;
                log.phase = RunPhase::Aborted;
                let frame = log.status_json(&self.meta);
                log.push_frame("status", frame);
                drop(log);
                self.shared.notify();
                true
            }
            RunPhase::Running => {
                if let Some(probe) = &log.probe {
                    probe.abort();
                }
                true
            }
            _ => false,
        }
    }

    /// Transition `Queued -> Running`: start the session on the daemon's
    /// synthetic compute and hand it to a drain thread. Called by the
    /// scheduler with the pool slots already reserved. Returns the drain
    /// thread handle, or the startup error (the run is then `Failed`).
    pub(crate) fn start(
        &self,
        on_alert: impl Fn(Alert) + Send + 'static,
        on_terminal: impl FnOnce(&str) + Send + 'static,
    ) -> Result<std::thread::JoinHandle<()>> {
        let mut log = self.shared.lock();
        let (plan, model) = log
            .pending
            .take()
            .ok_or_else(|| anyhow!("run {} has no pending plan", self.meta.id))?;
        let comp = SyntheticCompute::new(model.b_train, model.b_gen, model.max_seq)
            .with_delays(TRAIN_DELAY, GEN_DELAY);
        let session = match Session::start_with_compute(&plan, model.layout.clone(), comp)
            .with_context(|| format!("start session for run {}", self.meta.id))
        {
            Ok(s) => s,
            Err(e) => {
                log.phase = RunPhase::Failed(format!("{e:#}"));
                let frame = log.status_json(&self.meta);
                log.push_frame("status", frame);
                drop(log);
                self.shared.notify();
                return Err(e);
            }
        };
        log.probe = Some(session.probe());
        log.phase = RunPhase::Running;
        let frame = log.status_json(&self.meta);
        log.push_frame("status", frame);
        drop(log);
        self.shared.notify();

        let entry = self.clone();
        std::thread::Builder::new()
            .name(format!("sparrowrld-drain-{}", self.meta.id))
            .spawn(move || {
                drain(entry, session, on_alert, on_terminal);
            })
            .map_err(|e| anyhow!("spawn drain thread: {e}"))
    }

    /// The model preset a daemon run may use. Daemon-hosted runs are
    /// synthetic (the control plane has no PJRT artifacts), so the
    /// catalog is the bench-model axis.
    pub fn resolve_model(name: &str) -> Option<BenchModel> {
        bench_model(name)
    }
}

/// The drain loop: fold every session event into the run log, then
/// record the terminal state and notify the scheduler.
fn drain(
    entry: RunEntry,
    mut session: Session,
    on_alert: impl Fn(Alert),
    on_terminal: impl FnOnce(&str),
) {
    while let Some(ev) = session.recv() {
        let fired = {
            let mut log = entry.shared.lock();
            fold_event(&entry, &mut log, &ev)
        };
        entry.shared.notify();
        // Global delivery happens with the run lock released (lock
        // order: daemon state before run log, never the reverse).
        for alert in fired {
            on_alert(alert);
        }
    }
    // Channel closed: the runtime returned. join() yields the report or
    // the typed abort/failure error.
    let terminal = match session.join() {
        Ok(report) => {
            let checksum = report.steps.last().map(|s| s.checksum_hex());
            let mut log = entry.shared.lock();
            log.final_checksum = checksum;
            RunPhase::Finished.apply(&entry, &mut log);
            RunPhase::Finished
        }
        Err(e) => {
            let rendered = format!("{e:#}");
            let phase = if rendered.contains(ABORT_MSG) {
                RunPhase::Aborted
            } else {
                RunPhase::Failed(rendered)
            };
            let mut log = entry.shared.lock();
            phase.clone().apply(&entry, &mut log);
            phase
        }
    };
    entry.shared.notify();
    debug_assert!(terminal.is_terminal());
    on_terminal(&entry.meta.id);
}

impl RunPhase {
    /// Set the terminal phase and emit its `status` frame (caller holds
    /// the log lock and notifies after releasing it).
    fn apply(self, entry: &RunEntry, log: &mut RunLog) {
        log.phase = self;
        log.probe = None;
        let frame = log.status_json(&entry.meta);
        log.push_frame("status", frame);
    }
}

/// Fold one event: SSE frame + analytics + alert evaluation. Returns
/// alerts to deliver globally (after the lock is released).
fn fold_event(entry: &RunEntry, log: &mut RunLog, ev: &Event) -> Vec<Alert> {
    log.analytics.on_event(ev);
    if let Some((name, data)) = frame_for(ev) {
        log.push_frame(name, data);
    }
    let mut fired = Vec::new();
    match ev {
        Event::StepCompleted(_) => {
            fired = log.alert_engine.evaluate(&entry.meta.id, &log.analytics);
        }
        Event::Failover { actor, requeued, reason } => {
            fired.push(log.alert_engine.failover(
                &entry.meta.id,
                *actor,
                *requeued,
                *reason,
                log.analytics.steps,
            ));
        }
        _ => {}
    }
    for alert in &fired {
        log.push_frame("alert", alert.to_json());
        log.alerts.push(alert.clone());
    }
    fired
}

/// Map a session event to its SSE rendering (`None` = not streamed;
/// `Finished` is represented by the terminal `status` frame instead of
/// duplicating the whole report).
fn frame_for(ev: &Event) -> Option<(&'static str, Json)> {
    Some(match ev {
        Event::SftStep { step, loss } => (
            "sft_step",
            Json::obj().set("step", *step).set("loss", *loss as f64),
        ),
        Event::StepCompleted(log) => (
            "step",
            Json::obj()
                .set("step", log.step)
                .set("loss", log.loss as f64)
                .set("reward", log.mean_reward as f64)
                .set("rho", log.rho)
                .set("payload_bytes", log.payload_bytes)
                .set("dense_bytes", log.dense_bytes)
                .set("gen_tokens", log.gen_tokens)
                .set("checksum", log.checksum_hex()),
        ),
        Event::DeltaStreamed { version, payload_bytes, stripes } => (
            "delta",
            Json::obj()
                .set("version", *version)
                .set("payload_bytes", *payload_bytes)
                .set("stripes", *stripes),
        ),
        Event::Committed { version, checksum } => (
            "commit",
            Json::obj()
                .set("version", *version)
                .set("checksum", crate::util::hex(checksum)),
        ),
        Event::Joined { actor, version, bootstrap, bytes } => (
            "join",
            Json::obj()
                .set("actor", *actor)
                .set("version", *version)
                .set("bootstrap", bootstrap.name())
                .set("bytes", *bytes),
        ),
        Event::Draining { actor, requeued } => (
            "drain",
            Json::obj().set("actor", *actor).set("requeued", *requeued),
        ),
        Event::Preempted { actor } => ("preempt", Json::obj().set("actor", *actor)),
        Event::Failover { actor, requeued, reason } => (
            "failover",
            Json::obj()
                .set("actor", *actor)
                .set("requeued", *requeued)
                .set("reason", reason.to_string()),
        ),
        Event::Swapped { actor, model, version, bytes } => (
            "swap",
            Json::obj()
                .set("actor", *actor)
                .set("model", model.as_str())
                .set("version", *version)
                .set("bytes", *bytes),
        ),
        Event::Autoscale { version, decision } => (
            "autoscale",
            Json::obj()
                .set("version", *version)
                .set("decision", decision.name())
                .set("marginal_tpd", decision.marginal_tpd()),
        ),
        Event::Finished(_) => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> RunMeta {
        RunMeta {
            id: "r1".into(),
            model: "syn-xs".into(),
            steps: 3,
            seed: 7,
            n_actors: 2,
            regions: 1,
            transport: "inproc".into(),
            mode: "pipelined",
        }
    }

    fn queued_entry() -> RunEntry {
        let model = bench_model("syn-xs").unwrap();
        let plan = crate::session::RunSpec::synthetic()
            .actors(2)
            .steps(3)
            .deterministic()
            .build()
            .unwrap();
        RunEntry::queued(meta(), plan, model, AlertRules::default())
    }

    #[test]
    fn queued_entry_starts_with_a_status_frame() {
        let entry = queued_entry();
        assert_eq!(entry.phase(), RunPhase::Queued);
        let log = entry.shared.lock();
        let (frames, gap) = log.frames_from(0);
        assert!(!gap);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].event, "status");
        assert!(frames[0].data.contains("\"queued\""));
    }

    #[test]
    fn aborting_a_queued_run_terminates_it_without_a_session() {
        let entry = queued_entry();
        assert!(entry.request_abort());
        assert_eq!(entry.phase(), RunPhase::Aborted);
        assert!(entry.shared.lock().pending.is_none());
        // A second abort is a no-op on a terminal run.
        assert!(!entry.request_abort());
    }

    #[test]
    fn frame_log_evicts_but_reports_the_gap() {
        let entry = queued_entry();
        {
            let mut log = entry.shared.lock();
            for i in 0..(MAX_FRAMES + 10) {
                log.push_frame("step", Json::obj().set("i", i));
            }
        }
        let log = entry.shared.lock();
        let (from_zero, gap) = log.frames_from(0);
        assert!(gap, "evicted history must be reported as a gap");
        assert_eq!(from_zero.len(), MAX_FRAMES);
        let newest = from_zero.last().unwrap().seq;
        let (tail, gap2) = log.frames_from(newest);
        assert!(!gap2);
        assert_eq!(tail.len(), 1);
    }

    #[test]
    fn frame_mapping_covers_the_event_taxonomy() {
        let (name, data) = frame_for(&Event::Committed { version: 3, checksum: [7u8; 32] })
            .unwrap();
        assert_eq!(name, "commit");
        assert!(data.to_string().contains("0707"));
        let (name, _) = frame_for(&Event::Preempted { actor: 2 }).unwrap();
        assert_eq!(name, "preempt");
        let (name, data) = frame_for(&Event::Failover {
            actor: 1,
            requeued: 4,
            reason: crate::rt::FailReason::Crash,
        })
        .unwrap();
        assert_eq!(name, "failover");
        assert!(data.to_string().contains("crash"));
    }
}
