//! The accept loop: one `std::net::TcpListener`, one thread per
//! connection (bounded by [`DaemonConfig::max_connections`]), one
//! request per connection.
//!
//! A control plane sees a handful of requests per second; thread-per-
//! connection with hard caps is simpler to audit than an event loop and
//! fails closed — every socket carries [`http::READ_TIMEOUT`], every
//! parse failure maps to a 4xx, and the connection count cap turns an
//! accept flood into 503s instead of thread exhaustion.

use super::http::{self, HttpError, Response};
use super::routes;
use super::state::{DaemonConfig, DaemonState};
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// The daemon's front door: [`Daemon::spawn`] binds, starts the accept
/// thread, and returns a [`DaemonHandle`].
pub struct Daemon;

impl Daemon {
    /// Bind `cfg.addr` (port 0 = ephemeral) and start serving. The
    /// returned handle owns the daemon; dropping it shuts everything
    /// down (abort all runs, join all threads).
    pub fn spawn(cfg: DaemonConfig) -> Result<DaemonHandle> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind control plane on {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolve bound address")?;
        let state = Arc::new(DaemonState::new(cfg));
        let accept_state = state.clone();
        let conns = Arc::new(AtomicUsize::new(0));
        let accept = std::thread::Builder::new()
            .name("sparrowrld-accept".to_string())
            .spawn(move || accept_loop(listener, accept_state, conns))
            .context("spawn accept thread")?;
        Ok(DaemonHandle { addr, state, accept: Some(accept) })
    }
}

/// A running daemon. [`DaemonHandle::shutdown`] (or drop) stops the
/// accept loop, aborts every hosted session, and joins all threads.
pub struct DaemonHandle {
    addr: SocketAddr,
    state: Arc<DaemonState>,
    accept: Option<JoinHandle<()>>,
}

impl DaemonHandle {
    /// The actually-bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared state, for in-process inspection (tests, the CLI's status
    /// printout).
    pub fn state(&self) -> &Arc<DaemonState> {
        &self.state
    }

    /// Block forever serving (the `sparrowrl serve` foreground path).
    pub fn wait(mut self) {
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
    }

    /// Orderly stop: refuse new work, unblock the accept loop, abort
    /// all sessions, join all daemon threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.state.shutdown_all();
        // `accept()` has no timeout; a throwaway self-connection makes
        // the loop observe the shutdown flag and exit.
        let _ = TcpStream::connect(self.addr);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
    }
}

impl Drop for DaemonHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown_inner();
        }
    }
}

fn accept_loop(listener: TcpListener, state: Arc<DaemonState>, conns: Arc<AtomicUsize>) {
    for stream in listener.incoming() {
        if state.is_shutdown() {
            break;
        }
        let Ok(stream) = stream else { continue };
        // Connection cap: fail closed with a 503 instead of spawning
        // unboundedly under an accept flood.
        if conns.load(Ordering::Relaxed) >= state.cfg.max_connections {
            let mut stream = stream;
            let _ = http::write_response(
                &mut stream,
                &Response::json(
                    503,
                    routes::error_body("Busy", "connection limit reached; retry"),
                ),
            );
            continue;
        }
        conns.fetch_add(1, Ordering::Relaxed);
        let state = state.clone();
        let conns = conns.clone();
        let spawned = std::thread::Builder::new()
            .name("sparrowrld-conn".to_string())
            .spawn(move || {
                handle_connection(&state, stream);
                conns.fetch_sub(1, Ordering::Relaxed);
            });
        if spawned.is_err() {
            conns.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

fn handle_connection(state: &Arc<DaemonState>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(http::READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    match http::read_request(&mut stream) {
        Ok(req) => routes::handle(state, &req, &mut stream),
        Err(e) => {
            let resp = match &e {
                HttpError::BadRequest(_) => {
                    Response::json(400, routes::error_body("Parse", &e.to_string()))
                }
                HttpError::HeadTooLarge => {
                    Response::json(431, routes::error_body("HeadTooLarge", &e.to_string()))
                }
                HttpError::BodyTooLarge(_) => {
                    Response::json(413, routes::error_body("BodyTooLarge", &e.to_string()))
                }
                // Socket died mid-request: nobody left to answer.
                HttpError::Io(_) => return,
            };
            let _ = http::write_response(&mut stream, &resp);
        }
    }
}
