//! Per-run live analytics: the registry's drain thread folds every
//! [`Event`] through one [`Analytics`] so `GET /runs/{id}` can answer
//! with current overlap / payload / throughput / economics figures
//! without touching the session thread.
//!
//! All smoothed gauges are [`util::Ema`]s; the dollar figures reuse the
//! exact [`cost`] model the CLI's `exp wan` table is built from, so a
//! daemon snapshot and the paper-table tooling can never disagree about
//! what a byte of egress costs.

use crate::cost;
use crate::metrics::SpanKind;
use crate::session::Event;
use crate::util::json::Json;
use crate::util::Ema;
use std::time::Instant;

/// EMA smoothing for the per-step gauges (≈ last three steps dominate).
const BETA: f64 = 0.7;

/// Steady-state analytics for one run, updated event-by-event.
pub struct Analytics {
    started: Instant,
    last_step_at: Option<Instant>,
    /// RL steps folded so far (not the same as the step counter inside a
    /// resumed run — this counts what *this* daemon observed).
    pub steps: u64,
    /// Last policy version the trainer committed.
    pub last_version: u64,
    total_payload: u64,
    total_dense: u64,
    total_tokens: u64,
    failovers: u64,
    payload_ema: Ema,
    step_s_ema: Ema,
    rho_ema: Ema,
    overlap_ema: Ema,
    n_actors: usize,
    regions: usize,
    /// Authoritative figures once the run finished (from the
    /// `RunReport`'s timeline); they replace the live proxies.
    final_overlap: Option<f64>,
    final_wall_s: Option<f64>,
}

impl Analytics {
    pub fn new(n_actors: usize, regions: usize) -> Analytics {
        Analytics {
            started: Instant::now(),
            last_step_at: None,
            steps: 0,
            last_version: 0,
            total_payload: 0,
            total_dense: 0,
            total_tokens: 0,
            failovers: 0,
            payload_ema: Ema::new(BETA),
            step_s_ema: Ema::new(BETA),
            rho_ema: Ema::new(BETA),
            overlap_ema: Ema::new(BETA),
            n_actors: n_actors.max(1),
            regions: regions.max(1),
            final_overlap: None,
            final_wall_s: None,
        }
    }

    /// Fold one session event (called from the registry drain thread,
    /// under the run's log lock).
    pub fn on_event(&mut self, ev: &Event) {
        match ev {
            Event::StepCompleted(log) => {
                let now = Instant::now();
                if let Some(prev) = self.last_step_at {
                    self.step_s_ema.observe(now.duration_since(prev).as_secs_f64());
                }
                self.last_step_at = Some(now);
                self.steps += 1;
                self.total_payload += log.payload_bytes;
                self.total_dense += log.dense_bytes;
                self.total_tokens += log.gen_tokens;
                self.payload_ema.observe(log.payload_bytes as f64);
                self.rho_ema.observe(log.rho);
                // Live overlap proxy: the trainer-side sync work this
                // step (train + extract) counts as hidden up to the
                // concurrent rollout window — the same definition
                // `Timeline::overlap_ratio` applies to the real spans,
                // evaluated per step so it is available mid-run.
                let sync_ms = log.train_ms + log.extract_ms;
                if sync_ms > 0.0 {
                    self.overlap_ema.observe((log.rollout_ms.min(sync_ms)) / sync_ms);
                }
            }
            Event::Committed { version, .. } => self.last_version = *version,
            Event::Failover { .. } => self.failovers += 1,
            Event::Finished(report) => {
                self.final_overlap = Some(
                    report
                        .timeline
                        .overlap_ratio("trainer", &[SpanKind::Train, SpanKind::Extract]),
                );
                self.final_wall_s = Some(report.wall_s);
            }
            _ => {}
        }
    }

    /// Overlap ratio in [0, 1]: the timeline's authoritative figure once
    /// finished, the per-step EMA proxy while live.
    pub fn overlap(&self) -> f64 {
        self.final_overlap.unwrap_or_else(|| self.overlap_ema.get_or(1.0))
    }

    /// Smoothed delta payload per RL step, bytes.
    pub fn payload_per_step(&self) -> f64 {
        self.payload_ema.get_or(0.0)
    }

    /// Smoothed delta wire rate, bits per second of wall time.
    pub fn delta_bps(&self) -> f64 {
        let step_s = self.step_s_ema.get_or(0.0);
        if step_s <= 0.0 {
            return 0.0;
        }
        self.payload_ema.get_or(0.0) * 8.0 / step_s
    }

    /// Generated-token throughput over the whole observation window.
    pub fn tokens_per_s(&self) -> f64 {
        let wall = self.final_wall_s.unwrap_or_else(|| self.started.elapsed().as_secs_f64());
        if wall <= 0.0 {
            return 0.0;
        }
        self.total_tokens as f64 / wall
    }

    /// Tokens per dollar under the commodity WAN cost model, charging
    /// GPU-hours plus one delta copy of egress per region per step —
    /// identical accounting to `cost::Deployment` in the `exp wan` table.
    pub fn tokens_per_dollar(&self) -> f64 {
        let dep = cost::wan_deployment(self.regions, self.n_actors.div_ceil(self.regions));
        let egress_per_step = (self.payload_ema.get_or(0.0) * self.regions as f64) as u64;
        let step_s = self.step_s_ema.get_or(1.0).max(1e-6);
        dep.tokens_per_dollar_with_egress(self.tokens_per_s(), egress_per_step, step_s)
    }

    /// The JSON gauge block embedded in `GET /runs/{id}` responses.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("steps", self.steps)
            .set("last_version", self.last_version)
            .set("overlap", finite(self.overlap()))
            .set("payload_per_step_bytes", finite(self.payload_per_step()))
            .set("delta_bps", finite(self.delta_bps()))
            .set("rho", finite(self.rho_ema.get_or(0.0)))
            .set("step_s", finite(self.step_s_ema.get_or(0.0)))
            .set("tokens_per_s", finite(self.tokens_per_s()))
            .set("tokens_per_dollar", finite(self.tokens_per_dollar()))
            .set("total_payload_bytes", self.total_payload)
            .set("total_dense_bytes", self.total_dense)
            .set("total_gen_tokens", self.total_tokens)
            .set("failovers", self.failovers)
    }
}

/// The JSON layer has no NaN/Inf; clamp pathological gauges to 0.
fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::StepLog;

    fn step(step: u64, payload: u64, tokens: u64) -> Event {
        Event::StepCompleted(StepLog {
            step,
            loss: 1.0,
            mean_reward: 0.5,
            rho: 0.02,
            payload_bytes: payload,
            dense_bytes: payload * 40,
            gen_tokens: tokens,
            extract_ms: 2.0,
            train_ms: 6.0,
            rollout_ms: 12.0,
            policy_checksum: [0u8; 32],
        })
    }

    #[test]
    fn folds_steps_into_finite_gauges() {
        let mut a = Analytics::new(3, 1);
        for i in 1..=4 {
            a.on_event(&step(i, 10_000, 64));
            a.on_event(&Event::Committed { version: i, checksum: [0u8; 32] });
        }
        assert_eq!(a.steps, 4);
        assert_eq!(a.last_version, 4);
        // rollout (12ms) fully covers sync (8ms) → proxy saturates at 1.
        assert!((a.overlap() - 1.0).abs() < 1e-9, "overlap {}", a.overlap());
        assert!((a.payload_per_step() - 10_000.0).abs() < 1.0);
        assert!(a.rho_ema.get_or(0.0) > 0.0);
        assert!(a.tokens_per_dollar().is_finite());
    }

    #[test]
    fn overlap_proxy_reflects_exposed_sync_time() {
        let mut a = Analytics::new(3, 1);
        // rollout window (3ms) hides only 3 of 8 sync ms.
        a.on_event(&Event::StepCompleted(StepLog {
            rollout_ms: 3.0,
            ..match step(1, 1_000, 8) {
                Event::StepCompleted(l) => l,
                _ => unreachable!(),
            }
        }));
        assert!((a.overlap() - 3.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn json_snapshot_has_the_gauge_keys() {
        let mut a = Analytics::new(2, 2);
        a.on_event(&step(1, 5_000, 32));
        let j = a.to_json();
        for key in [
            "steps",
            "overlap",
            "payload_per_step_bytes",
            "delta_bps",
            "tokens_per_s",
            "tokens_per_dollar",
            "total_payload_bytes",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        // Round-trips through the shared JSON writer/parser.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("steps").and_then(Json::as_u64), Some(1));
    }
}
