//! Route dispatch for the control plane.
//!
//! | Route                        | Purpose                                     |
//! |------------------------------|---------------------------------------------|
//! | `GET  /`                     | daemon identity + pool occupancy            |
//! | `GET  /healthz`              | liveness probe (`ok`)                       |
//! | `POST /runs`                 | submit a RunSpec JSON → `201 {"id":...}`    |
//! | `GET  /runs`                 | all runs, compact rows                      |
//! | `GET  /runs/{id}`            | full snapshot (status, analytics, checksum) |
//! | `POST /runs/{id}/abort`      | cooperative abort (idempotent)              |
//! | `GET  /runs/{id}/events`     | SSE: replay + live tail of the event stream |
//! | `POST /runs/{id}/swap`       | script a hot-swap onto a *queued* run       |
//! | `GET  /models`               | model-registry listing                      |
//! | `POST /models`               | publish a durable run into the registry     |
//! | `GET  /alerts`               | daemon-wide fired alerts                    |
//!
//! Error contract: malformed JSON / unknown fields → 400 with
//! `{"error":{"kind":"Parse",...}}`; a spec that parses but fails the
//! builder's legality checks → 422 carrying the *typed*
//! [`SpecError`](crate::session::SpecError) variant name as `kind`, so
//! clients can branch without string-matching prose. Registry routes
//! carry the typed [`RecoveryError`](crate::delta::RecoveryError)
//! taxonomy the same way: unknown model/version → 404, a daemon started
//! without `--registry` (or a registry/run-dir mixup, or a manifest
//! conflict) → 409.

use super::http::{self, Request, Response};
use super::registry::{RunEntry, RunPhase};
use super::state::{DaemonState, SubmitError};
use crate::bench::scenario::{bench_model, BenchModel};
use crate::delta::{expect_run_dir, DurableStore, ModelRegistry, RecoveryError};
use crate::session::{Backend, RunPlan, RunSpec, SpecError};
use crate::util::json::Json;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// How long an SSE subscriber parks between condvar wakeups before
/// re-checking the daemon shutdown flag.
const SSE_POLL: Duration = Duration::from_millis(250);

/// Dispatch one parsed request. SSE responses stream directly to the
/// socket; everything else returns a framed [`Response`].
pub(crate) fn handle(state: &Arc<DaemonState>, req: &Request, stream: &mut TcpStream) {
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/") => index(state),
        ("GET", "/healthz") => Response::text(200, "ok\n"),
        ("POST", "/runs") => submit(state, req),
        ("GET", "/runs") => Response::json(200, state.list_json().to_string()),
        ("GET", "/models") => list_models(state),
        ("POST", "/models") => publish_model(state, req),
        ("GET", "/alerts") => Response::json(200, state.alerts_json().to_string()),
        (method, path) => match run_subroute(path) {
            Some((id, tail)) => match (method, tail) {
                ("GET", "") => match state.find(id) {
                    Some(entry) => Response::json(200, entry.snapshot().to_string()),
                    None => not_found(id),
                },
                ("POST", "/abort") => match state.find(id) {
                    Some(entry) => {
                        entry.request_abort();
                        Response::json(200, entry.snapshot().to_string())
                    }
                    None => not_found(id),
                },
                ("GET", "/events") => match state.find(id) {
                    Some(entry) => return stream_events(state, &entry, stream),
                    None => not_found(id),
                },
                ("POST", "/swap") => match state.find(id) {
                    Some(entry) => swap_run(state, &entry, req),
                    None => not_found(id),
                },
                (_, "") | (_, "/abort") | (_, "/events") | (_, "/swap") => method_not_allowed(),
                _ => Response::json(404, error_body("NotFound", "no such route")),
            },
            None => match (method, path) {
                // Known paths with the wrong verb get a 405, not a 404.
                ("POST", "/") | ("POST", "/healthz") | ("POST", "/alerts") => {
                    method_not_allowed()
                }
                ("PUT" | "DELETE" | "PATCH" | "HEAD", _) => method_not_allowed(),
                _ => Response::json(404, error_body("NotFound", "no such route")),
            },
        },
    };
    let _ = http::write_response(stream, &resp);
}

/// Split `/runs/{id}` and `/runs/{id}/...` into `(id, tail)`.
fn run_subroute(path: &str) -> Option<(&str, &str)> {
    let rest = path.strip_prefix("/runs/")?;
    let (id, tail) = match rest.find('/') {
        Some(pos) => (&rest[..pos], &rest[pos..]),
        None => (rest, ""),
    };
    if id.is_empty() {
        return None;
    }
    Some((id, tail))
}

fn index(state: &Arc<DaemonState>) -> Response {
    let body = Json::obj()
        .set("daemon", "sparrowrld")
        .set("version", env!("CARGO_PKG_VERSION"))
        .set("pool", state.pool_json())
        .set(
            "routes",
            vec![
                "GET /healthz",
                "POST /runs",
                "GET /runs",
                "GET /runs/{id}",
                "POST /runs/{id}/abort",
                "GET /runs/{id}/events",
                "POST /runs/{id}/swap",
                "GET /models",
                "POST /models",
                "GET /alerts",
            ],
        );
    Response::json(200, body.to_string())
}

fn not_found(id: &str) -> Response {
    Response::json(404, error_body("UnknownRun", &format!("no run {id:?}")))
}

fn method_not_allowed() -> Response {
    Response::json(405, error_body("MethodNotAllowed", "wrong verb for this route"))
}

pub(crate) fn error_body(kind: &str, message: &str) -> String {
    Json::obj()
        .set("error", Json::obj().set("kind", kind).set("message", message))
        .to_string()
}

/// Map the registry's typed error taxonomy onto the HTTP contract:
/// unknown names/versions are 404s, structural conflicts (wrong kind of
/// directory, manifest contradictions, base mismatches) are 409s, and
/// anything else (I/O, corrupt objects) is a 500.
fn registry_error(err: &RecoveryError) -> Response {
    let (status, kind) = match err {
        RecoveryError::UnknownModel { .. } => (404, "UnknownModel"),
        RecoveryError::UnknownModelVersion { .. } => (404, "UnknownModelVersion"),
        RecoveryError::NotARegistry { .. } => (409, "NotARegistry"),
        RecoveryError::NotARun { .. } => (409, "NotARun"),
        RecoveryError::RegistryConflict { .. } => (409, "RegistryConflict"),
        RecoveryError::BaseMismatch { .. } => (409, "BaseMismatch"),
        _ => (500, "Registry"),
    };
    Response::json(status, error_body(kind, &err.to_string()))
}

/// The registry the daemon was started with, or the 409 every registry
/// route returns without one.
fn open_registry(state: &Arc<DaemonState>) -> Result<ModelRegistry, Response> {
    let Some(dir) = &state.cfg.registry else {
        return Err(Response::json(
            409,
            error_body("NoRegistry", "daemon was started without --registry DIR"),
        ));
    };
    ModelRegistry::open(dir).map_err(|e| registry_error(&e))
}

/// `GET /models`: the registry namespace (models, versions, shared
/// bases) as JSON.
fn list_models(state: &Arc<DaemonState>) -> Response {
    match open_registry(state) {
        Ok(reg) => Response::json(200, reg.to_json().to_string()),
        Err(resp) => resp,
    }
}

/// `POST /models`: publish a durable run directory into the registry.
/// Body: `{"run_dir": "...", "name": "...", "model": "syn-xs",
/// "version": N?}` — `model` names the bench layout preset the run was
/// trained with (the registry stores only its fingerprint).
fn publish_model(state: &Arc<DaemonState>, req: &Request) -> Response {
    let mut reg = match open_registry(state) {
        Ok(reg) => reg,
        Err(resp) => return resp,
    };
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return Response::json(400, error_body("Parse", &e.to_string())),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Response::json(400, error_body("Parse", &e)),
    };
    let Some(run_dir) = json.get("run_dir").and_then(Json::as_str).map(str::to_string) else {
        return Response::json(400, error_body("Parse", "field \"run_dir\" must be a string"));
    };
    let Some(name) = json.get("name").and_then(Json::as_str).map(str::to_string) else {
        return Response::json(400, error_body("Parse", "field \"name\" must be a string"));
    };
    let model_name = json
        .get("model")
        .and_then(Json::as_str)
        .unwrap_or("syn-xs")
        .to_string();
    let version = json.get("version").and_then(Json::as_u64);
    let Some(model) = bench_model(&model_name) else {
        return Response::json(
            422,
            error_body("UnknownModel", &format!("unknown bench model {model_name:?}")),
        );
    };
    if let Err(e) = expect_run_dir(std::path::Path::new(&run_dir)) {
        return registry_error(&e);
    }
    let store = match DurableStore::open(&run_dir) {
        Ok(s) => s,
        Err(e) => return registry_error(&e),
    };
    match reg.publish(&store, &model.layout, &name, version) {
        Ok(report) => Response::json(
            201,
            Json::obj()
                .set("model", report.model.as_str())
                .set("version", report.version)
                .set("object", report.object.as_str())
                .set("payload_bytes", report.payload_bytes)
                .set("base", report.base.as_str())
                .set("base_was_new", report.base_was_new)
                .set("object_was_new", report.object_was_new)
                .to_string(),
        ),
        Err(e) => registry_error(&e),
    }
}

/// `POST /runs/{id}/swap`: amend a **queued** run's plan with a scripted
/// hot-swap. Body: `{"actor": N, "model": "...", "version": N}`. The
/// target must already be published; a running or terminal run is a 409
/// (`NotQueued`) — daemon swaps are scripted at admission, executed by
/// the runtime's swap epilogue.
fn swap_run(state: &Arc<DaemonState>, entry: &RunEntry, req: &Request) -> Response {
    let reg = match open_registry(state) {
        Ok(reg) => reg,
        Err(resp) => return resp,
    };
    let reg_dir = state.cfg.registry.as_ref().expect("open_registry checked");
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return Response::json(400, error_body("Parse", &e.to_string())),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Response::json(400, error_body("Parse", &e)),
    };
    let Some(actor) = json.get("actor").and_then(Json::as_u64) else {
        return Response::json(
            400,
            error_body("Parse", "field \"actor\" must be a non-negative integer"),
        );
    };
    let Some(model) = json.get("model").and_then(Json::as_str).map(str::to_string) else {
        return Response::json(400, error_body("Parse", "field \"model\" must be a string"));
    };
    let Some(version) = json.get("version").and_then(Json::as_u64) else {
        return Response::json(
            400,
            error_body("Parse", "field \"version\" must be a non-negative integer"),
        );
    };
    // Validate the target against the registry before touching the run —
    // an unknown fine-tune is a 404 regardless of run phase.
    if let Err(e) = reg.version_ref(&model, version) {
        return registry_error(&e);
    }
    let mut log = entry.shared.lock();
    if log.phase != RunPhase::Queued {
        return Response::json(
            409,
            error_body(
                "NotQueued",
                &format!(
                    "run {} is {}; swaps are scripted onto queued runs only",
                    entry.meta.id,
                    log.phase.name()
                ),
            ),
        );
    }
    let Some((plan, _)) = log.pending.as_mut() else {
        return Response::json(409, error_body("NotQueued", "run has no pending plan"));
    };
    match plan.add_swap(reg_dir, actor as u32, &model, version) {
        Ok(()) => Response::json(
            200,
            Json::obj()
                .set("run", entry.meta.id.as_str())
                .set("actor", actor)
                .set("model", model.as_str())
                .set("version", version)
                .to_string(),
        ),
        Err(err) => Response::json(422, error_body(err.name(), &err.to_string())),
    }
}

/// `POST /runs`: parse → build → admit.
fn submit(state: &Arc<DaemonState>, req: &Request) -> Response {
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return Response::json(400, error_body("Parse", &e.to_string())),
    };
    let (plan, model, transport, seed) = match parse_run_spec(body) {
        Ok(parts) => parts,
        Err(SubmitReject::Parse(msg)) => return Response::json(400, error_body("Parse", &msg)),
        Err(SubmitReject::Spec(err)) => {
            return Response::json(422, error_body(err.name(), &err.to_string()))
        }
    };
    match state.submit(plan, model, transport, seed) {
        Ok(entry) => Response::json(
            201,
            Json::obj()
                .set("id", entry.meta.id.as_str())
                .set("status", entry.phase().name())
                .to_string(),
        ),
        Err(err @ SubmitError::ExceedsActorPool { .. }) => {
            Response::json(422, error_body(err.kind(), &err.message()))
        }
        Err(err @ SubmitError::TableFull { .. }) => {
            Response::json(503, error_body(err.kind(), &err.message()))
        }
    }
}

enum SubmitReject {
    /// Body is not the JSON shape we accept → 400.
    Parse(String),
    /// Shape is fine; the combination is illegal → 422 with the typed
    /// `SpecError` variant name.
    Spec(SpecError),
}

/// Accepted submission fields (all optional except none):
/// `model` (bench preset, default `syn-xs`), `steps`, `sft_steps`,
/// `actors`, `group_size`, `max_new_tokens`, `segment_bytes`, `seed`,
/// `lease_sweep_ms`, `lr_rl`, `lr_sft`, `temperature`, `mode`
/// (`pipelined`/`sequential`), `transport` (`inproc`/`sim`/`tcp`),
/// `wan` (preset name), `deterministic` (default **true** — daemon runs
/// are replayable unless asked otherwise), `autoscale`.
fn parse_run_spec(body: &str) -> Result<(RunPlan, BenchModel, String, u64), SubmitReject> {
    let json = Json::parse(body).map_err(SubmitReject::Parse)?;
    let Json::Obj(fields) = &json else {
        return Err(SubmitReject::Parse("run spec must be a JSON object".into()));
    };

    let mut spec = RunSpec::synthetic();
    let mut model_name = "syn-xs".to_string();
    let mut transport_name = "inproc".to_string();
    let mut seed = 0u64;
    let mut deterministic = true;

    for (key, value) in fields {
        match key.as_str() {
            "model" => model_name = str_field(value, key)?,
            "steps" => spec = spec.steps(u64_field(value, key)?),
            "sft_steps" => spec = spec.sft_steps(u64_field(value, key)?),
            "actors" => spec = spec.actors(u64_field(value, key)? as usize),
            "group_size" => spec = spec.group_size(u64_field(value, key)? as usize),
            "max_new_tokens" => spec = spec.max_new_tokens(u64_field(value, key)? as usize),
            "segment_bytes" => spec = spec.segment_bytes(u64_field(value, key)? as usize),
            "seed" => seed = u64_field(value, key)?,
            "lease_sweep_ms" => spec = spec.lease_sweep_ms(u64_field(value, key)?),
            "lr_rl" => spec = spec.lr_rl(f64_field(value, key)? as f32),
            "lr_sft" => spec = spec.lr_sft(f64_field(value, key)? as f32),
            "temperature" => spec = spec.temperature(f64_field(value, key)? as f32),
            "wan" => spec = spec.wan(&str_field(value, key)?),
            "deterministic" => deterministic = bool_field(value, key)?,
            "autoscale" => {
                if bool_field(value, key)? {
                    spec = spec.autoscale();
                }
            }
            "mode" => match str_field(value, key)?.as_str() {
                "pipelined" => spec = spec.pipelined(),
                "sequential" => spec = spec.sequential(),
                other => {
                    return Err(SubmitReject::Parse(format!(
                        "mode must be \"pipelined\" or \"sequential\", got {other:?}"
                    )))
                }
            },
            "transport" => {
                transport_name = str_field(value, key)?;
                match Backend::parse(&transport_name) {
                    Some(backend) => spec = spec.transport(backend),
                    None => {
                        return Err(SubmitReject::Parse(format!(
                            "unknown transport {transport_name:?} (one of {:?})",
                            Backend::NAMES
                        )))
                    }
                }
            }
            other => {
                return Err(SubmitReject::Parse(format!(
                    "unknown field {other:?} in run spec"
                )))
            }
        }
    }

    // The daemon's model catalog is the bench-preset axis; an unknown
    // name is the same typed error the builder would raise.
    let Some(model) = bench_model(&model_name) else {
        return Err(SubmitReject::Spec(SpecError::UnknownModel(model_name)));
    };
    spec = spec.seed(seed);
    if deterministic {
        spec = spec.deterministic();
    }
    let plan = spec.build().map_err(SubmitReject::Spec)?;
    Ok((plan, model, transport_name, seed))
}

fn str_field(v: &Json, key: &str) -> Result<String, SubmitReject> {
    v.as_str()
        .map(str::to_string)
        .ok_or_else(|| SubmitReject::Parse(format!("field {key:?} must be a string")))
}

fn u64_field(v: &Json, key: &str) -> Result<u64, SubmitReject> {
    v.as_u64()
        .ok_or_else(|| SubmitReject::Parse(format!("field {key:?} must be a non-negative integer")))
}

fn f64_field(v: &Json, key: &str) -> Result<f64, SubmitReject> {
    v.as_f64()
        .ok_or_else(|| SubmitReject::Parse(format!("field {key:?} must be a number")))
}

fn bool_field(v: &Json, key: &str) -> Result<bool, SubmitReject> {
    v.as_bool()
        .ok_or_else(|| SubmitReject::Parse(format!("field {key:?} must be a boolean")))
}

/// `GET /runs/{id}/events`: replay the retained frame log from seq 0,
/// then tail live frames until the run is terminal (or the daemon shuts
/// down / the client disconnects).
fn stream_events(state: &Arc<DaemonState>, entry: &RunEntry, stream: &mut TcpStream) {
    if http::write_sse_head(stream).is_err() {
        return;
    }
    let mut next_seq = 0u64;
    loop {
        // Collect under the run lock; write with it released.
        let (frames, gap, terminal) = {
            let mut log = entry.shared.lock();
            loop {
                let (frames, gap) = log.frames_from(next_seq);
                let terminal = log.phase.is_terminal();
                if !frames.is_empty() || terminal || state.is_shutdown() {
                    break (frames, gap, terminal || state.is_shutdown());
                }
                let (guard, _timeout) = entry
                    .shared
                    .cv
                    .wait_timeout(log, SSE_POLL)
                    .expect("run log poisoned");
                log = guard;
            }
        };
        if gap && write!(stream, ": log truncated, resuming from oldest retained frame\n\n").is_err()
        {
            return;
        }
        for frame in &frames {
            next_seq = frame.seq + 1;
            if write!(
                stream,
                "id: {}\nevent: {}\ndata: {}\n\n",
                frame.seq, frame.event, frame.data
            )
            .is_err()
            {
                return; // subscriber hung up
            }
        }
        let _ = stream.flush();
        if terminal && frames.is_empty() {
            return; // everything replayed and the run is done
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_subroute_splits_ids_and_tails() {
        assert_eq!(run_subroute("/runs/r1"), Some(("r1", "")));
        assert_eq!(run_subroute("/runs/r1/abort"), Some(("r1", "/abort")));
        assert_eq!(run_subroute("/runs/r1/events"), Some(("r1", "/events")));
        assert_eq!(run_subroute("/runs/"), None);
        assert_eq!(run_subroute("/alerts"), None);
    }

    #[test]
    fn parse_defaults_are_deterministic_syn_xs() {
        let (plan, model, transport, seed) = parse_run_spec("{\"steps\": 3}").unwrap();
        assert_eq!(model.name, "syn-xs");
        assert_eq!(transport, "inproc");
        assert_eq!(seed, 0);
        assert_eq!(plan.config().steps, 3);
        assert!(plan.config().deterministic);
    }

    #[test]
    fn parse_rejects_unknown_fields_and_bad_types() {
        match parse_run_spec("{\"stepz\": 3}") {
            Err(SubmitReject::Parse(msg)) => assert!(msg.contains("stepz"), "{msg}"),
            _ => panic!("unknown field must be a parse reject"),
        }
        match parse_run_spec("{\"steps\": \"three\"}") {
            Err(SubmitReject::Parse(msg)) => assert!(msg.contains("steps"), "{msg}"),
            _ => panic!("bad type must be a parse reject"),
        }
        match parse_run_spec("not json at all") {
            Err(SubmitReject::Parse(_)) => {}
            _ => panic!("non-JSON must be a parse reject"),
        }
        match parse_run_spec("[1,2,3]") {
            Err(SubmitReject::Parse(msg)) => assert!(msg.contains("object"), "{msg}"),
            _ => panic!("non-object must be a parse reject"),
        }
    }

    #[test]
    fn illegal_spec_combinations_surface_the_typed_error() {
        // actors=0 trips the builder's ZeroActors check.
        match parse_run_spec("{\"actors\": 0}") {
            Err(SubmitReject::Spec(err)) => assert_eq!(err.name(), "ZeroActors"),
            _ => panic!("expected a typed SpecError"),
        }
        // wan + explicit actors is the builder's conflict check.
        match parse_run_spec("{\"wan\": \"wan-2\", \"actors\": 3}") {
            Err(SubmitReject::Spec(err)) => {
                assert_eq!(err.name(), "ActorsConflictWithWan")
            }
            _ => panic!("expected a typed SpecError"),
        }
        // Unknown model rides the same typed channel.
        match parse_run_spec("{\"model\": \"syn-xxl\"}") {
            Err(SubmitReject::Spec(err)) => assert_eq!(err.name(), "UnknownModel"),
            _ => panic!("expected a typed SpecError"),
        }
    }

    #[test]
    fn error_bodies_are_parseable_json() {
        let body = error_body("ZeroActors", "a run needs at least one actor");
        let json = Json::parse(&body).unwrap();
        let err = json.get("error").unwrap();
        assert_eq!(err.get("kind").and_then(Json::as_str), Some("ZeroActors"));
    }
}
