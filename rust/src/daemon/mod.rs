//! `sparrowrld`: the multi-session control-plane daemon.
//!
//! One long-running process hosts **many** concurrent RL training
//! sessions over one shared synthetic actor pool, exposing a small
//! HTTP/1.1 + JSON surface (hand-rolled over `std::net` — zero new
//! dependencies, same hostile-input discipline as `rt::net`):
//!
//! * `POST /runs` — submit a run spec (JSON); illegal specs come back
//!   as 422s carrying the *typed* [`SpecError`](crate::session::SpecError)
//!   variant name.
//! * `GET /runs`, `GET /runs/{id}` — table rows / full snapshot with
//!   live analytics (overlap, payload/step, delta bps, tokens/$ under
//!   the [`cost`](crate::cost) model).
//! * `POST /runs/{id}/abort` — cooperative abort, idempotent.
//! * `GET /runs/{id}/events` — the session's typed [`Event`]
//!   (crate::session::Event) stream as server-sent events: full replay
//!   from the bounded frame log, then a live tail until terminal.
//! * `GET /alerts` — daemon-wide threshold alerts ([`AlertRules`]).
//!
//! Cross-session arbitration: a submitted run declares its actor need;
//! the FIFO scheduler in [`state`] starts it only when the shared pool
//! has the slots and the session cap has room — submissions past
//! capacity **queue, never oversubscribe** (see the module docs in
//! [`state`] and docs/ARCHITECTURE.md §2f).
//!
//! In-process embedding (what the loopback tests and the CI smoke do):
//!
//! ```no_run
//! use sparrowrl::daemon::{Daemon, DaemonConfig, http_get, http_post};
//!
//! let handle = Daemon::spawn(DaemonConfig {
//!     addr: "127.0.0.1:0".into(), // ephemeral port
//!     ..DaemonConfig::default()
//! })
//! .unwrap();
//! let addr = handle.addr();
//! let resp = http_post(addr, "/runs", "{\"steps\": 3, \"actors\": 2}").unwrap();
//! assert_eq!(resp.status, 201);
//! let list = http_get(addr, "/runs").unwrap();
//! assert_eq!(list.status, 200);
//! handle.shutdown();
//! ```

pub mod alerts;
pub mod analytics;
pub mod http;
pub mod registry;
pub mod routes;
pub mod server;
pub mod state;

pub use alerts::{Alert, AlertRules};
pub use analytics::Analytics;
pub use http::{http_get, http_post, HttpResponse, SseClient, SseEvent};
pub use registry::{RunEntry, RunMeta, RunPhase, SseFrame};
pub use server::{Daemon, DaemonHandle};
pub use state::{DaemonConfig, DaemonState, SubmitError};
