//! Threshold alerting over the live analytics stream.
//!
//! Each run carries one [`AlertEngine`] holding the daemon-wide
//! [`AlertRules`]. The registry drain thread evaluates it after every
//! folded step; fired alerts land in the run's SSE stream (as `alert`
//! events) *and* in the daemon-wide list behind `GET /alerts`.
//!
//! Threshold rules are **latched**: a run that sits below the overlap
//! floor for 50 steps produces one alert, not 50 — the alert marks the
//! transition into the bad regime, the live gauges on `GET /runs/{id}`
//! tell you whether it is still there. Failover alerts are per-event
//! (each lost actor is its own incident).

use super::analytics::Analytics;
use crate::rt::FailReason;
use crate::util::json::Json;
use std::collections::BTreeSet;

/// Steps to observe before threshold rules arm — EMAs over the first
/// step or two are all transient.
const WARMUP_STEPS: u64 = 2;

/// Daemon-wide alert thresholds (`None` disables a rule). Configured
/// once at daemon start (`serve --alert-*`); every run is measured
/// against the same bars.
#[derive(Clone, Debug, Default)]
pub struct AlertRules {
    /// Fire when a run's overlap ratio drops below this floor — the
    /// bandwidth barrier is showing (sync time no longer hidden).
    pub overlap_floor: Option<f64>,
    /// Fire when projected tokens/$ drops below this floor — the run is
    /// burning commodity-fleet economics.
    pub tokens_per_dollar_floor: Option<f64>,
    /// Fire when the smoothed delta payload per step exceeds this many
    /// bytes — sparsity collapsed, deltas are going dense.
    pub payload_ceiling_bytes: Option<u64>,
}

impl AlertRules {
    pub fn any_enabled(&self) -> bool {
        self.overlap_floor.is_some()
            || self.tokens_per_dollar_floor.is_some()
            || self.payload_ceiling_bytes.is_some()
    }
}

/// One fired alert, as stored globally and rendered into SSE frames.
#[derive(Clone, Debug)]
pub struct Alert {
    pub run_id: String,
    /// Stable rule tag: `overlap_floor`, `tokens_per_dollar_floor`,
    /// `payload_ceiling`, or `failover`.
    pub rule: &'static str,
    pub message: String,
    /// The run step at which the rule fired.
    pub step: u64,
    pub value: f64,
    pub threshold: f64,
}

impl Alert {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("run", self.run_id.as_str())
            .set("rule", self.rule)
            .set("message", self.message.as_str())
            .set("step", self.step)
            .set("value", self.value)
            .set("threshold", self.threshold)
    }
}

/// Per-run evaluator: the rules plus which threshold rules already
/// latched for this run.
pub(crate) struct AlertEngine {
    rules: AlertRules,
    fired: BTreeSet<&'static str>,
}

impl AlertEngine {
    pub(crate) fn new(rules: AlertRules) -> AlertEngine {
        AlertEngine { rules, fired: BTreeSet::new() }
    }

    /// Evaluate the threshold rules against the current gauges; returns
    /// only alerts newly fired by this evaluation.
    pub(crate) fn evaluate(&mut self, run_id: &str, a: &Analytics) -> Vec<Alert> {
        let mut out = Vec::new();
        if a.steps < WARMUP_STEPS {
            return out;
        }
        if let Some(floor) = self.rules.overlap_floor {
            let v = a.overlap();
            if v < floor && self.fired.insert("overlap_floor") {
                out.push(Alert {
                    run_id: run_id.to_string(),
                    rule: "overlap_floor",
                    message: format!(
                        "overlap ratio {v:.3} fell below the {floor:.3} floor: delta sync is no longer hidden inside rollout"
                    ),
                    step: a.steps,
                    value: v,
                    threshold: floor,
                });
            }
        }
        if let Some(floor) = self.rules.tokens_per_dollar_floor {
            let v = a.tokens_per_dollar();
            if v < floor && self.fired.insert("tokens_per_dollar_floor") {
                out.push(Alert {
                    run_id: run_id.to_string(),
                    rule: "tokens_per_dollar_floor",
                    message: format!(
                        "projected {v:.0} tokens/$ fell below the {floor:.0} floor under the commodity WAN cost model"
                    ),
                    step: a.steps,
                    value: v,
                    threshold: floor,
                });
            }
        }
        if let Some(ceiling) = self.rules.payload_ceiling_bytes {
            let v = a.payload_per_step();
            if v > ceiling as f64 && self.fired.insert("payload_ceiling") {
                out.push(Alert {
                    run_id: run_id.to_string(),
                    rule: "payload_ceiling",
                    message: format!(
                        "delta payload {} per step exceeds the {} ceiling: update sparsity collapsed",
                        crate::util::fmt_bytes(v as u64),
                        crate::util::fmt_bytes(ceiling)
                    ),
                    step: a.steps,
                    value: v,
                    threshold: ceiling as f64,
                });
            }
        }
        out
    }

    /// Failovers always alert, once per event (never latched): each is a
    /// distinct membership incident the operator should see.
    pub(crate) fn failover(
        &mut self,
        run_id: &str,
        actor: u32,
        requeued: u64,
        reason: FailReason,
        step: u64,
    ) -> Alert {
        Alert {
            run_id: run_id.to_string(),
            rule: "failover",
            message: format!(
                "actor {actor} lost ({reason}); {requeued} leased prompts re-issued to survivors"
            ),
            step,
            value: requeued as f64,
            threshold: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::StepLog;
    use crate::session::Event;

    fn analytics_with_steps(n: u64, rollout_ms: f64) -> Analytics {
        let mut a = Analytics::new(3, 1);
        for i in 1..=n {
            a.on_event(&Event::StepCompleted(StepLog {
                step: i,
                loss: 1.0,
                mean_reward: 0.5,
                rho: 0.02,
                payload_bytes: 50_000,
                dense_bytes: 2_000_000,
                gen_tokens: 64,
                extract_ms: 2.0,
                train_ms: 6.0,
                rollout_ms,
                policy_checksum: [0u8; 32],
            }));
        }
        a
    }

    #[test]
    fn overlap_floor_fires_once_and_latches() {
        let rules = AlertRules { overlap_floor: Some(0.9), ..AlertRules::default() };
        let mut engine = AlertEngine::new(rules);
        // rollout 3ms vs 8ms sync → overlap 0.375, below the 0.9 floor.
        let a = analytics_with_steps(3, 3.0);
        let first = engine.evaluate("r1", &a);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].rule, "overlap_floor");
        assert_eq!(first[0].run_id, "r1");
        // Same bad regime on the next step: latched, no repeat.
        assert!(engine.evaluate("r1", &a).is_empty());
    }

    #[test]
    fn threshold_rules_hold_fire_during_warmup() {
        let rules = AlertRules { overlap_floor: Some(0.9), ..AlertRules::default() };
        let mut engine = AlertEngine::new(rules);
        let a = analytics_with_steps(1, 3.0);
        assert!(engine.evaluate("r1", &a).is_empty());
    }

    #[test]
    fn payload_ceiling_fires_when_deltas_go_dense() {
        let rules =
            AlertRules { payload_ceiling_bytes: Some(10_000), ..AlertRules::default() };
        let mut engine = AlertEngine::new(rules);
        let a = analytics_with_steps(3, 12.0); // 50 KB/step > 10 KB ceiling
        let fired = engine.evaluate("r1", &a);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, "payload_ceiling");
        assert!(fired[0].message.contains("ceiling"));
    }

    #[test]
    fn quiet_run_with_no_rules_never_alerts() {
        let mut engine = AlertEngine::new(AlertRules::default());
        let a = analytics_with_steps(5, 3.0);
        assert!(engine.evaluate("r1", &a).is_empty());
        assert!(!AlertRules::default().any_enabled());
    }

    #[test]
    fn failover_alerts_are_per_event() {
        let mut engine = AlertEngine::new(AlertRules::default());
        let a1 = engine.failover("r2", 1, 4, crate::rt::FailReason::Crash, 3);
        let a2 = engine.failover("r2", 2, 0, crate::rt::FailReason::Stall, 4);
        assert_eq!(a1.rule, "failover");
        assert!(a1.message.contains("crash"));
        assert!(a2.message.contains("stall"));
        let j = a1.to_json();
        assert_eq!(j.get("rule").and_then(|v| v.as_str()), Some("failover"));
    }
}
