//! Minimal HTTP/1.1 framing over `std::net` — the daemon's wire layer
//! and its test client, with zero dependencies.
//!
//! Server side: [`read_request`] parses one request with the same
//! hostile-input rules as `rt::net` — every length is validated against
//! hard caps *before* any allocation ([`MAX_HEAD_BYTES`],
//! [`MAX_BODY_BYTES`]), parse failures are typed [`HttpError`]s mapped
//! to 4xx responses (never panics, never unbounded buffering), and the
//! caller is expected to arm socket read timeouts so a stalled peer
//! cannot wedge a connection thread. One request per connection
//! (`Connection: close`) keeps the state machine trivial.
//!
//! Client side: [`http_get`] / [`http_post`] and the [`SseClient`]
//! server-sent-events reader are the "curl-free" helpers the loopback
//! test suite and the CI smoke drive the daemon with.

use anyhow::{anyhow, bail, Context, Result};
use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Cap on the request line + headers. A control-plane request head is a
/// few hundred bytes; a peer streaming an unterminated head is cut off
/// here instead of growing the buffer forever.
pub const MAX_HEAD_BYTES: usize = 16 << 10;

/// Cap on a request body (a `RunSpec` JSON is well under 1 KiB). The
/// `Content-Length` value is checked against this *before* the body
/// buffer is allocated — a hostile length cannot drive a huge reserve.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Server-side socket read timeout: a peer that stops mid-request is
/// dropped instead of pinning a connection thread.
pub const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed request. `path` is the target without the query string
/// (`query` keeps it, undecoded); `body` is fully read.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Option<String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::BadRequest("body is not valid UTF-8".into()))
    }
}

/// Typed request-parse failure, mapped to a 4xx by the server loop.
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line / headers / body framing → 400.
    BadRequest(String),
    /// Head grew past [`MAX_HEAD_BYTES`] → 431.
    HeadTooLarge,
    /// Declared `Content-Length` past [`MAX_BODY_BYTES`] → 413.
    BodyTooLarge(usize),
    /// Socket error or timeout mid-request: nothing to answer.
    Io(std::io::Error),
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::BadRequest(m) => write!(f, "bad request: {m}"),
            HttpError::HeadTooLarge => {
                write!(f, "request head exceeds {MAX_HEAD_BYTES} bytes")
            }
            HttpError::BodyTooLarge(n) => {
                write!(f, "request body of {n} bytes exceeds {MAX_BODY_BYTES}")
            }
            HttpError::Io(e) => write!(f, "socket error: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// Read and parse one HTTP/1.1 request. Length caps are enforced before
/// allocation; the stream should already carry a read timeout.
pub fn read_request<R: Read>(stream: &mut R) -> Result<Request, HttpError> {
    // Accumulate the head in bounded chunks, scanning for CRLFCRLF.
    // Bytes past the terminator (the body prefix) stay in `buf`.
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() >= MAX_HEAD_BYTES {
            return Err(HttpError::HeadTooLarge);
        }
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk).map_err(HttpError::Io)?;
        if n == 0 {
            return Err(HttpError::BadRequest("connection closed mid-head".into()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::BadRequest("head is not valid UTF-8".into()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(HttpError::BadRequest(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::BadRequest(format!("unsupported version {version:?}")));
    }
    let mut content_length: usize = 0;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::BadRequest(format!("malformed header {line:?}")));
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse::<usize>()
                .map_err(|_| HttpError::BadRequest(format!("bad content-length {value:?}")))?;
        }
    }
    // Validate the declared length against the cap BEFORE allocating —
    // the same count-vs-allocation rule as `rt::net::Msg` decoding.
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError::BodyTooLarge(content_length));
    }
    let mut body = buf[head_end + 4..].to_vec();
    if body.len() > content_length {
        return Err(HttpError::BadRequest("body longer than content-length".into()));
    }
    let already = body.len();
    body.resize(content_length, 0);
    stream.read_exact(&mut body[already..]).map_err(HttpError::Io)?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Ok(Request { method: method.to_string(), path, query, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// One response; [`write_response`] frames it with `Connection: close`.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response { status, content_type: "application/json", body: body.into().into_bytes() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response { status, content_type: "text/plain", body: body.into().into_bytes() }
    }
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

pub fn write_response<W: Write>(w: &mut W, r: &Response) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        r.status,
        reason(r.status),
        r.content_type,
        r.body.len()
    )?;
    w.write_all(&r.body)?;
    w.flush()
}

/// Begin a server-sent-event response; the caller then writes
/// `event:`/`data:`/`id:` frames until the stream ends.
pub fn write_sse_head<W: Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

// ---------------------------------------------------------------------
// Client helpers (tests, examples, CI smoke — no curl required)
// ---------------------------------------------------------------------

/// A parsed client-side response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: String,
    pub body: String,
}

fn client_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<HttpResponse> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    stream.set_nodelay(true)?;
    let body = body.unwrap_or("");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    read_client_response(&mut BufReader::new(stream))
}

fn read_client_response<R: BufRead>(r: &mut R) -> Result<HttpResponse> {
    let mut status_line = String::new();
    r.read_line(&mut status_line).context("read status line")?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;
    let mut content_type = String::new();
    let mut content_length: Option<usize> = None;
    loop {
        let mut line = String::new();
        r.read_line(&mut line).context("read header")?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-type") {
                content_type = value.trim().to_string();
            } else if name.eq_ignore_ascii_case("content-length") {
                content_length = Some(value.trim().parse().context("bad content-length")?);
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            if n > MAX_BODY_BYTES {
                bail!("response body of {n} bytes exceeds the client cap");
            }
            body.resize(n, 0);
            r.read_exact(&mut body).context("read body")?;
        }
        None => {
            r.read_to_end(&mut body).context("read body to close")?;
        }
    }
    Ok(HttpResponse {
        status,
        content_type,
        body: String::from_utf8(body).context("response body not UTF-8")?,
    })
}

/// Blocking GET against a daemon.
pub fn http_get(addr: SocketAddr, path: &str) -> Result<HttpResponse> {
    client_request(addr, "GET", path, None)
}

/// Blocking POST with a JSON (or empty) body.
pub fn http_post(addr: SocketAddr, path: &str, body: &str) -> Result<HttpResponse> {
    client_request(addr, "POST", path, Some(body))
}

/// One server-sent event as the client sees it.
#[derive(Clone, Debug, PartialEq)]
pub struct SseEvent {
    pub event: String,
    pub data: String,
    pub id: Option<u64>,
}

/// Incremental SSE reader over a live daemon connection.
pub struct SseClient {
    reader: BufReader<TcpStream>,
}

impl SseClient {
    /// GET `path` and check the stream handshake (200 + event-stream).
    pub fn connect(addr: SocketAddr, path: &str) -> Result<SseClient> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        stream.set_nodelay(true)?;
        write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        if !status_line.contains("200") {
            bail!("SSE handshake failed: {}", status_line.trim());
        }
        let mut saw_event_stream = false;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if line.to_ascii_lowercase().starts_with("content-type")
                && line.contains("text/event-stream")
            {
                saw_event_stream = true;
            }
        }
        if !saw_event_stream {
            bail!("SSE handshake: response is not text/event-stream");
        }
        Ok(SseClient { reader })
    }

    /// The next event, or `None` once the server closed the stream.
    /// Comment lines (`: ...`) are skipped; multiple `data:` lines join
    /// with newlines per the SSE spec.
    pub fn next_event(&mut self) -> Result<Option<SseEvent>> {
        let mut event = String::new();
        let mut data: Vec<String> = Vec::new();
        let mut id = None;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).context("read SSE line")?;
            if n == 0 {
                return Ok(None); // clean end of stream
            }
            let line = line.trim_end_matches(['\r', '\n']);
            if line.is_empty() {
                if event.is_empty() && data.is_empty() {
                    continue; // stray separator
                }
                return Ok(Some(SseEvent {
                    event: if event.is_empty() { "message".into() } else { event },
                    data: data.join("\n"),
                    id,
                }));
            }
            if let Some(rest) = line.strip_prefix("event:") {
                event = rest.trim().to_string();
            } else if let Some(rest) = line.strip_prefix("data:") {
                data.push(rest.trim_start().to_string());
            } else if let Some(rest) = line.strip_prefix("id:") {
                id = rest.trim().parse::<u64>().ok();
            }
            // Lines starting with ':' are comments; anything else is
            // ignored per the SSE spec.
        }
    }

    /// Drain until an event with name `wanted` arrives; errors if the
    /// stream ends first. `seen` collects everything along the way.
    pub fn wait_for(&mut self, wanted: &str, seen: &mut Vec<SseEvent>) -> Result<SseEvent> {
        while let Some(ev) = self.next_event()? {
            seen.push(ev.clone());
            if ev.event == wanted {
                return Ok(ev);
            }
        }
        bail!("SSE stream ended before an {wanted:?} event")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_request_with_body_and_query() {
        let req = parse(
            "POST /runs?verbose=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\n{\"steps\":3}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/runs");
        assert_eq!(req.query.as_deref(), Some("verbose=1"));
        assert_eq!(req.body_str().unwrap(), "{\"steps\":3}");
    }

    #[test]
    fn parses_bodyless_get() {
        let req = parse("GET /runs HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_malformed_request_lines() {
        for raw in [
            "\r\n\r\n",
            "GET\r\n\r\n",
            "GET /x\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
            "GET /x SPDY/99\r\n\r\n",
        ] {
            assert!(matches!(parse(raw), Err(HttpError::BadRequest(_))), "{raw:?}");
        }
    }

    #[test]
    fn hostile_content_length_rejected_before_allocation() {
        // Claims 4 GiB; the typed error must come from the cap check,
        // not from an attempted allocation or a read timeout.
        let raw = format!(
            "POST /runs HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            4usize << 30
        );
        assert!(matches!(parse(&raw), Err(HttpError::BodyTooLarge(_))));
        // Non-numeric and negative lengths are malformed, not defaulted.
        assert!(matches!(
            parse("POST /runs HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            parse("POST /runs HTTP/1.1\r\nContent-Length: -5\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
    }

    #[test]
    fn unterminated_head_is_cut_at_the_cap() {
        // A head that never sends CRLFCRLF stops growing at MAX_HEAD_BYTES.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        while raw.len() <= MAX_HEAD_BYTES {
            raw.push_str("X-Pad: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        assert!(matches!(parse(&raw), Err(HttpError::HeadTooLarge)));
    }

    #[test]
    fn truncated_body_errors_instead_of_hanging() {
        let raw = "POST /runs HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort";
        assert!(matches!(parse(raw), Err(HttpError::Io(_))));
    }

    #[test]
    fn response_frames_with_length_and_close() {
        let mut out = Vec::new();
        write_response(&mut out, &Response::json(201, "{\"id\":\"r1\"}")).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 201 Created\r\n"), "{s}");
        assert!(s.contains("Content-Length: 11\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("{\"id\":\"r1\"}"));
    }

    #[test]
    fn client_parses_response_with_content_length() {
        let raw = "HTTP/1.1 422 Unprocessable Entity\r\nContent-Type: application/json\r\nContent-Length: 2\r\n\r\n{}";
        let resp = read_client_response(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(resp.status, 422);
        assert_eq!(resp.content_type, "application/json");
        assert_eq!(resp.body, "{}");
    }
}
