//! Configuration: model presets, GPU classes, WAN region profiles, and
//! cloud pricing — the knobs the paper's evaluation (§7) turns.
//!
//! Two kinds of model specs exist:
//! * **Runnable** — the `sparrow-*` family with a full `ModelLayout`,
//!   AOT-compiled to PJRT artifacts and executed for real.
//! * **Analytic** — the paper's Qwen3-4B/8B/14B, used by the discrete-event
//!   simulator (their compute happens on GPUs we do not have; §7's claims
//!   depend only on sizes, durations, and link parameters).

pub mod presets;

pub use presets::*;

use crate::delta::ModelLayout;

/// A model the system can train/serve.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Full tensor layout for runnable models; analytic models carry a
    /// synthetic layout with the right total size.
    pub layout: ModelLayout,
    /// Transformer hyperparameters (0 for purely analytic entries).
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    /// Whether artifacts can actually be built & executed for this model.
    pub runnable: bool,
    /// Expected per-step nonzero update ratio (measured for runnable
    /// models; paper-reported for analytic models — Fig 3 / Table 4).
    pub expected_rho: f64,
}

impl ModelSpec {
    pub fn total_params(&self) -> u64 {
        self.layout.total_params()
    }

    pub fn dense_bytes_bf16(&self) -> u64 {
        self.layout.dense_bytes_bf16()
    }
}

/// GPU class with the calibrated performance priors the scheduler and the
/// simulator use (§7.1: H100 vs A100 differ 2-3x; §5.3's worked example
/// uses 5000 vs 2500 tokens/s).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GpuClass {
    H100,
    A100,
    L40,
}

impl GpuClass {
    /// Rollout generation throughput prior, tokens/s per GPU (for a mid-
    /// size ~8B policy; scaled by model size in the simulator).
    pub fn rollout_tokens_per_s(self) -> f64 {
        match self {
            GpuClass::H100 => 5000.0,
            GpuClass::A100 => 2500.0,
            GpuClass::L40 => 1700.0,
        }
    }

    /// Relative training speed (H100 = 1).
    pub fn train_speed(self) -> f64 {
        match self {
            GpuClass::H100 => 1.0,
            GpuClass::A100 => 0.45,
            GpuClass::L40 => 0.30,
        }
    }

    /// On-demand $/GPU/hr (Table 1/6 sources: Hyperbolic, Prime Intellect).
    pub fn on_demand_per_hr(self) -> f64 {
        match self {
            GpuClass::H100 => 1.49,
            GpuClass::A100 => 1.24,
            GpuClass::L40 => 0.60,
        }
    }

    /// Reserved RDMA-fabric $/GPU/hr (Table 6: 8xH100 cluster $19.92/hr).
    pub fn reserved_rdma_per_hr(self) -> f64 {
        match self {
            GpuClass::H100 => 2.49,
            GpuClass::A100 => 2.10,
            GpuClass::L40 => 1.10,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GpuClass::H100 => "H100",
            GpuClass::A100 => "A100",
            GpuClass::L40 => "L40",
        }
    }

    pub fn parse(s: &str) -> Option<GpuClass> {
        match s.to_ascii_lowercase().as_str() {
            "h100" => Some(GpuClass::H100),
            "a100" => Some(GpuClass::A100),
            "l40" => Some(GpuClass::L40),
            _ => None,
        }
    }
}

/// WAN link profile from the Trainer (US) to a region — §7.1's testbed plus
/// the §7.5 multi-DC regions. Bandwidth is the bottleneck capacity; `loss`
/// feeds the Mathis single-TCP throughput ceiling.
#[derive(Clone, Copy, Debug)]
pub struct RegionProfile {
    pub name: &'static str,
    /// Bottleneck capacity, bits/s.
    pub bandwidth_bps: f64,
    /// Round-trip time, seconds.
    pub rtt_s: f64,
    /// Packet loss probability.
    pub loss: f64,
    /// Relative bandwidth jitter (std/mean) — cross-cloud links fluctuate
    /// (paper: 500 Mbps - 1 Gbps measured on US-Canada).
    pub jitter: f64,
}

impl RegionProfile {
    pub const fn new(
        name: &'static str,
        bandwidth_bps: f64,
        rtt_s: f64,
        loss: f64,
        jitter: f64,
    ) -> Self {
        RegionProfile { name, bandwidth_bps, rtt_s, loss, jitter }
    }
}

/// The §7 testbed regions (calibrated to reproduce the paper's measured
/// numbers: e.g. 202 MB over US-Canada single TCP = 4.71 s -> ~343 Mbps
/// effective under loss, within the 0.5-1 Gbps fluctuating link).
pub mod regions {
    use super::RegionProfile;

    // Loss rates are *residual* TCP-visible loss (what the Mathis ceiling
    // sees), calibrated so the US-Canada link reproduces the paper's §7.3
    // measurements: 202 MB single-stream = 4.71 s (~343 Mbps effective),
    // 4 streams = 2.90 s (~557 Mbps) on a 0.5-1 Gbps fluctuating link.
    pub const US_LOCAL: RegionProfile =
        RegionProfile::new("us-local", 800e9, 0.000_05, 0.0, 0.0); // RDMA 800 Gbps
    pub const CANADA: RegionProfile =
        RegionProfile::new("canada", 0.75e9, 0.030, 1.3e-6, 0.18);
    pub const JAPAN: RegionProfile =
        RegionProfile::new("japan", 2.0e9, 0.150, 1.5e-6, 0.20);
    pub const NETHERLANDS: RegionProfile =
        RegionProfile::new("netherlands", 1.5e9, 0.090, 1.0e-6, 0.20);
    pub const ICELAND: RegionProfile =
        RegionProfile::new("iceland", 1.2e9, 0.120, 1.2e-6, 0.20);
    pub const AUSTRALIA: RegionProfile =
        RegionProfile::new("australia", 1.0e9, 0.200, 1.8e-6, 0.25);

    pub fn by_name(name: &str) -> Option<RegionProfile> {
        Some(match name.to_ascii_lowercase().as_str() {
            "us-local" | "us" => US_LOCAL,
            "canada" | "ca" => CANADA,
            "japan" | "jp" => JAPAN,
            "netherlands" | "nl" => NETHERLANDS,
            "iceland" | "is" => ICELAND,
            "australia" | "au" => AUSTRALIA,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_priors_match_paper_ratios() {
        // §5.3's worked example: H100 5000 tok/s vs A100 2500 splits
        // a batch of 300 into 200/100.
        let h = GpuClass::H100.rollout_tokens_per_s();
        let a = GpuClass::A100.rollout_tokens_per_s();
        assert_eq!(h / a, 2.0);
        // 2-3x spread across the fleet (§2.3 C2).
        let l = GpuClass::L40.rollout_tokens_per_s();
        assert!(h / l > 2.0 && h / l < 3.5);
    }

    #[test]
    fn table6_hourly_costs() {
        // 4xH100 + 8xA100 on-demand = $15.88/hr; 8xH100 RDMA = $19.92/hr.
        let sparrow_8b = 4.0 * GpuClass::H100.on_demand_per_hr()
            + 8.0 * GpuClass::A100.on_demand_per_hr();
        assert!((sparrow_8b - 15.88).abs() < 1e-9, "{sparrow_8b}");
        let single_dc_8b = 8.0 * GpuClass::H100.reserved_rdma_per_hr();
        assert!((single_dc_8b - 19.92).abs() < 1e-9);
        // 14B rows: 6xH100 + 12xA100 = $23.82; 2x8xH100 = $39.84.
        let sparrow_14b = 6.0 * GpuClass::H100.on_demand_per_hr()
            + 12.0 * GpuClass::A100.on_demand_per_hr();
        assert!((sparrow_14b - 23.82).abs() < 1e-9);
        assert!((16.0 * GpuClass::H100.reserved_rdma_per_hr() - 39.84).abs() < 1e-9);
    }

    #[test]
    fn region_lookup() {
        assert_eq!(regions::by_name("canada").unwrap().name, "canada");
        assert_eq!(regions::by_name("AU").unwrap().name, "australia");
        assert!(regions::by_name("mars").is_none());
    }
}
