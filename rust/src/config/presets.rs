//! Model presets: the runnable `sparrow-*` family (trained/served for real
//! through PJRT) and analytic Qwen3 descriptors for the simulator.

use super::ModelSpec;
use crate::delta::ModelLayout;

/// Construct a runnable transformer spec.
fn runnable(
    name: &str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    max_seq: usize,
) -> ModelSpec {
    ModelSpec {
        name: name.to_string(),
        layout: ModelLayout::transformer(name, vocab, d_model, n_layers, d_ff),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        max_seq,
        runnable: true,
        expected_rho: 0.01, // refined by `sparrowrl exp fig3` measurements
    }
}

/// Analytic model: layout sized to the published parameter count, never
/// compiled. `rho` is the paper-reported per-step nonzero ratio.
fn analytic(name: &str, params: u64, rho: f64) -> ModelSpec {
    // One giant pseudo-tensor per billion params keeps index spaces <2^32.
    let chunk: u64 = 1 << 30;
    let mut tensors = Vec::new();
    let mut left = params;
    let mut i = 0;
    while left > 0 {
        let n = left.min(chunk);
        tensors.push(crate::delta::TensorSpec::new(
            &format!("blob{i}"),
            &[n as usize],
        ));
        left -= n;
        i += 1;
    }
    ModelSpec {
        name: name.to_string(),
        layout: ModelLayout::new(name, tensors),
        vocab: 0,
        d_model: 0,
        n_layers: 0,
        n_heads: 0,
        d_ff: 0,
        max_seq: 0,
        runnable: false,
        expected_rho: rho,
    }
}

/// Look up a model preset by name.
pub fn model(name: &str) -> Option<ModelSpec> {
    Some(match name {
        // --- runnable family (AOT-compiled, really executed) ---
        // ~0.15M params: CI-size smoke model.
        "sparrow-xs" => runnable("sparrow-xs", 256, 64, 2, 4, 256, 64),
        // ~1.1M params: default for tests and quickstart.
        "sparrow-s" => runnable("sparrow-s", 512, 128, 4, 8, 512, 64),
        // ~6.6M params.
        "sparrow-m" => runnable("sparrow-m", 1024, 256, 6, 8, 1024, 96),
        // ~34.6M params.
        "sparrow-l" => runnable("sparrow-l", 2048, 512, 8, 16, 2048, 128),
        // ~116M params: the end-to-end validation model (~100M target).
        "sparrow-xl" => runnable("sparrow-xl", 4096, 768, 12, 12, 3072, 128),

        // --- analytic (paper models; Fig 3 / Table 4 rho values) ---
        "qwen3-4b" => analytic("qwen3-4b", 4_020_000_000, 0.0112),
        "qwen3-8b" => analytic("qwen3-8b", 8_190_000_000, 0.0096),
        "qwen3-14b" => analytic("qwen3-14b", 14_800_000_000, 0.0100),
        "llama3-8b" => analytic("llama3-8b", 8_030_000_000, 0.0256),
        "glm4-9b" => analytic("glm4-9b", 9_400_000_000, 0.0199),
        "qwen2.5-72b" => analytic("qwen2.5-72b", 72_700_000_000, 0.0185),
        _ => return None,
    })
}

/// All runnable presets, small to large.
pub fn runnable_models() -> Vec<&'static str> {
    vec!["sparrow-xs", "sparrow-s", "sparrow-m", "sparrow-l", "sparrow-xl"]
}

/// The paper's evaluated sizes (Fig 8/11/12).
pub fn paper_models() -> Vec<&'static str> {
    vec!["qwen3-4b", "qwen3-8b", "qwen3-14b"]
}

/// A multi-region WAN deployment preset (§7.5 / Fig 13): which regions
/// host rollout actors and how many per region. The trainer hub is always
/// US-local; each region's WAN link profile comes from
/// [`regions`](super::regions).
#[derive(Clone, Debug)]
pub struct WanPreset {
    pub name: &'static str,
    /// Hub→region link profiles, in deployment order.
    pub regions: Vec<super::RegionProfile>,
    /// Rollout actors hosted in each region.
    pub actors_per_region: usize,
}

impl WanPreset {
    pub fn n_actors(&self) -> usize {
        self.regions.len() * self.actors_per_region
    }
}

/// The §7.5 region roll-out order: regions join in the order the paper
/// adds datacenters (Fig 13's 1-DC → 4-DC sweep).
pub fn wan_region_order() -> [super::RegionProfile; 4] {
    use super::regions;
    [regions::CANADA, regions::JAPAN, regions::NETHERLANDS, regions::ICELAND]
}

/// Every WAN preset name, in rollout order (`sparrowrl list` prints
/// these; `RunSpec::wan` accepts them).
pub const WAN_PRESET_NAMES: [&str; 4] = ["wan-1", "wan-2", "wan-3", "wan-4"];

/// Look up a WAN preset: `wan-N` (N = 1..=4) spreads actors over the
/// first N regions of [`wan_region_order`] (2 actors per region, the
/// paper's 8-actor fleet split evenly at 4 DCs).
pub fn wan_preset(name: &str) -> Option<WanPreset> {
    let idx = WAN_PRESET_NAMES.iter().position(|&n| n == name)?;
    let regions = wan_region_order()[..=idx].to_vec();
    Some(WanPreset { name: WAN_PRESET_NAMES[idx], regions, actors_per_region: 2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runnable_sizes_span_smoke_to_100m() {
        let xs = model("sparrow-xs").unwrap().total_params();
        let xl = model("sparrow-xl").unwrap().total_params();
        assert!(xs < 300_000, "xs={xs}");
        assert!(
            (90_000_000..150_000_000).contains(&xl),
            "xl={xl} should be ~100M"
        );
    }

    #[test]
    fn analytic_sizes_match_paper() {
        let m = model("qwen3-8b").unwrap();
        assert!(!m.runnable);
        assert_eq!(m.total_params(), 8_190_000_000);
        // ~16 GB in bf16 (Table 2).
        let gb = m.dense_bytes_bf16() as f64 / 1e9;
        assert!((15.0..17.5).contains(&gb), "{gb} GB");
        assert!((m.expected_rho - 0.0096).abs() < 1e-12);
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(model("gpt-17t").is_none());
    }

    #[test]
    fn wan_presets_scale_one_to_four_regions() {
        for n in 1..=4usize {
            let p = wan_preset(&format!("wan-{n}")).unwrap();
            assert_eq!(p.regions.len(), n);
            assert_eq!(p.n_actors(), 2 * n);
            // Every region has a real WAN profile (nonzero RTT + bandwidth).
            for r in &p.regions {
                assert!(r.bandwidth_bps > 0.0 && r.rtt_s > 0.0, "{}", r.name);
            }
        }
        assert_eq!(wan_preset("wan-1").unwrap().regions[0].name, "canada");
        assert!(wan_preset("wan-9").is_none());
    }

    #[test]
    fn analytic_chunks_stay_below_u32_index_space() {
        let m = model("qwen2.5-72b").unwrap();
        for t in &m.layout.tensors {
            assert!(t.numel() <= u32::MAX as u64);
        }
        assert_eq!(m.total_params(), 72_700_000_000);
    }
}
