//! Heterogeneity-aware job scheduling — the paper's Algorithm 1 (§5.3).
//!
//! Three mechanisms:
//! * **Adaptive allocation**: each step's batch B splits across eligible
//!   actors proportionally to EMA throughput estimates tau_a, so fast and
//!   slow actors finish together.
//! * **Version gating**: only actors on version v, or on v-1 with D_v
//!   staged (they get a Commit first), receive work. Actors further behind
//!   are excluded for the step and their tau decays by alpha so they
//!   rejoin conservatively.
//! * **Bandwidth-aware gating** (§5.2's "throughput- and bandwidth-aware
//!   scheduling", multi-region form): actors carry a region tag, each
//!   region's observed delta-distribution throughput feeds an EMA
//!   ([`Scheduler::observe_transfer`]), and
//!   [`Scheduler::allocate_bandwidth_aware`] shrinks the share of regions
//!   whose predicted delivery time exceeds the generation window — work
//!   shifts toward regions that can actually hide the next delta.
//!
//! ```
//! use sparrowrl::scheduler::{Scheduler, SchedulerConfig, VersionState};
//!
//! let mut s = Scheduler::new(SchedulerConfig::default());
//! s.register(0, 5000.0); // H100 prior, tokens/s
//! s.register(1, 2500.0); // A100 prior
//! for a in [0, 1] {
//!     s.observe_version(a, VersionState { active: 3, staged: None });
//! }
//! // The paper's §5.3 worked example: 300 requests split 200/100.
//! let alloc = s.allocate(3, 300);
//! assert_eq!(alloc[0].requests, 200);
//! assert_eq!(alloc[1].requests, 100);
//! ```

use crate::util::Ema;
use std::collections::BTreeMap;

pub type ActorId = u32;

/// Scheduler tunables (Algorithm 1's alpha/beta).
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Exclusion decay on tau for left-behind actors.
    pub alpha: f64,
    /// EMA history weight on settlement.
    pub beta: f64,
    /// Prior tokens/s for actors with no observations.
    pub default_tau: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { alpha: 0.5, beta: 0.7, default_tau: 2500.0 }
    }
}

/// Version state the gate inspects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VersionState {
    /// Currently active policy version.
    pub active: u64,
    /// Highest fully staged (but not yet committed) delta version.
    pub staged: Option<u64>,
}

#[derive(Clone, Debug)]
struct ActorEntry {
    tau: Ema,
    version: VersionState,
    alive: bool,
}

/// One actor's share of a step's batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub actor: ActorId,
    pub requests: u64,
    /// Actor is on v-1 with D_v staged: scheduler sends Commit(v) first.
    pub needs_commit: bool,
}

/// The Algorithm-1 scheduler.
pub struct Scheduler {
    cfg: SchedulerConfig,
    actors: BTreeMap<ActorId, ActorEntry>,
    /// Region tag per actor (multi-region deployments; untagged = local).
    region_of: BTreeMap<ActorId, usize>,
    /// Observed delta-distribution throughput per region, bytes/s EMA.
    region_bps: BTreeMap<usize, Ema>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            actors: BTreeMap::new(),
            region_of: BTreeMap::new(),
            region_bps: BTreeMap::new(),
        }
    }

    /// Tag an actor with its deployment region (for the bandwidth gate).
    pub fn set_region(&mut self, actor: ActorId, region: usize) {
        self.region_of.insert(actor, region);
    }

    /// Record one observed delta distribution into `region`: `bytes`
    /// delivered in `elapsed_s` seconds (WAN leg completion as seen by the
    /// hub or the netsim). Feeds the per-region throughput EMA.
    pub fn observe_transfer(&mut self, region: usize, bytes: u64, elapsed_s: f64) {
        if elapsed_s <= 0.0 {
            return;
        }
        self.region_bps
            .entry(region)
            .or_insert_with(|| Ema::new(self.cfg.beta))
            .observe(bytes as f64 / elapsed_s);
    }

    /// Observed distribution throughput of a region, bytes/s (None until
    /// the first observation).
    pub fn region_bps(&self, region: usize) -> Option<f64> {
        self.region_bps.get(&region).and_then(|e| e.get())
    }

    /// Bandwidth-gate scale for one actor: the fraction of its tau that
    /// survives given its region's predicted delivery time for
    /// `payload_bytes` against a `window_s` generation window. Regions
    /// that deliver within the window (or have no observations yet) keep
    /// their full share; a region predicted to take 2x the window keeps
    /// half, and so on — work shifts smoothly toward regions whose next
    /// delta will actually hide.
    fn bandwidth_scale(&self, actor: ActorId, payload_bytes: u64, window_s: f64) -> f64 {
        let Some(&region) = self.region_of.get(&actor) else {
            return 1.0;
        };
        let Some(bps) = self.region_bps(region) else {
            return 1.0;
        };
        if bps <= 0.0 || window_s <= 0.0 {
            return 1.0;
        }
        let predicted = payload_bytes as f64 / bps;
        (window_s / predicted.max(1e-9)).min(1.0)
    }

    /// Register an actor with a GPU-class prior (tokens/s).
    pub fn register(&mut self, actor: ActorId, prior_tau: f64) {
        self.actors.insert(
            actor,
            ActorEntry {
                tau: Ema::with_initial(self.cfg.beta, prior_tau),
                version: VersionState { active: 0, staged: None },
                alive: true,
            },
        );
    }

    /// Admit an elastically-joined actor mid-run: register its capability
    /// prior, record the version it was bootstrapped to (a fresh
    /// [`Self::register`] would claim version 0 and never pass the
    /// eligibility gate), and tag its region for the bandwidth gate. The
    /// caller invokes this only after the joiner's policy witness
    /// verified, so the version state is trustworthy.
    pub fn admit(&mut self, actor: ActorId, prior_tau: f64, version: u64, region: usize) {
        self.register(actor, prior_tau);
        self.observe_version(actor, VersionState { active: version, staged: None });
        self.set_region(actor, region);
    }

    pub fn deregister(&mut self, actor: ActorId) {
        if let Some(a) = self.actors.get_mut(&actor) {
            a.alive = false;
        }
    }

    pub fn set_alive(&mut self, actor: ActorId, alive: bool) {
        if let Some(a) = self.actors.get_mut(&actor) {
            a.alive = alive;
        }
    }

    /// Update an actor's version state (on staging/commit notifications).
    pub fn observe_version(&mut self, actor: ActorId, state: VersionState) {
        if let Some(a) = self.actors.get_mut(&actor) {
            a.version = state;
        }
    }

    /// Staging notification from the async runtime: `D_version` finished
    /// staging on `actor` (possibly mid-generation). Monotone — a late
    /// notification for an older delta never regresses the state.
    pub fn note_staged(&mut self, actor: ActorId, version: u64) {
        if let Some(a) = self.actors.get_mut(&actor) {
            if version > a.version.active && a.version.staged.map_or(true, |s| s < version) {
                a.version.staged = Some(version);
            }
        }
    }

    /// Commit notification: `actor` activated `version` at its safe point.
    pub fn note_committed(&mut self, actor: ActorId, version: u64) {
        if let Some(a) = self.actors.get_mut(&actor) {
            a.version.active = a.version.active.max(version);
            if a.version.staged.map_or(false, |s| s <= a.version.active) {
                a.version.staged = None;
            }
        }
    }

    pub fn tau(&self, actor: ActorId) -> Option<f64> {
        self.actors.get(&actor).and_then(|a| a.tau.get())
    }

    fn eligible(entry: &ActorEntry, v: u64) -> (bool, bool) {
        if !entry.alive {
            return (false, false);
        }
        let st = entry.version;
        if st.active == v {
            (true, false)
        } else if st.active + 1 == v && st.staged == Some(v) {
            // On v-1 with D_v staged: eligible, needs Commit(v).
            (true, true)
        } else {
            (false, false)
        }
    }

    /// Algorithm 1: split `batch` requests across eligible actors in
    /// proportion to tau. Floors are topped up by largest fractional
    /// remainder so the full batch is always assigned (avoiding the
    /// paper's implicit rounding loss). Ineligible live actors decay.
    pub fn allocate(&mut self, version: u64, batch: u64) -> Vec<Assignment> {
        self.allocate_scaled(version, batch, |_| 1.0)
    }

    /// Bandwidth-aware allocation (§5.2, multi-region): like
    /// [`allocate`](Self::allocate), but each actor's tau is additionally
    /// scaled by its region's distribution feasibility — the fraction of a
    /// `window_s` generation window its region's observed throughput needs
    /// to land a `payload_bytes` delta. Regions that hide the delta keep
    /// their full proportional share; starved regions shrink (but never
    /// hard-exclude: one WAN copy still flows, so they keep catching up).
    pub fn allocate_bandwidth_aware(
        &mut self,
        version: u64,
        batch: u64,
        payload_bytes: u64,
        window_s: f64,
    ) -> Vec<Assignment> {
        let scales: BTreeMap<ActorId, f64> = self
            .actors
            .keys()
            .map(|&id| (id, self.bandwidth_scale(id, payload_bytes, window_s)))
            .collect();
        self.allocate_scaled(version, batch, |id| scales.get(&id).copied().unwrap_or(1.0))
    }

    fn allocate_scaled(
        &mut self,
        version: u64,
        batch: u64,
        scale: impl Fn(ActorId) -> f64,
    ) -> Vec<Assignment> {
        let cfg = self.cfg;
        // Pass 1: eligible set + aggregate capacity T.
        let mut elig: Vec<(ActorId, f64, bool)> = Vec::new();
        let mut total_tau = 0.0;
        for (&id, e) in self.actors.iter() {
            let (ok, needs_commit) = Self::eligible(e, version);
            if ok {
                let t = (e.tau.get_or(cfg.default_tau) * scale(id)).max(1e-9);
                total_tau += t;
                elig.push((id, t, needs_commit));
            }
        }
        // Decay excluded-but-alive actors (Algorithm 1 line 14).
        for (&_id, e) in self.actors.iter_mut() {
            let (ok, _) = Self::eligible(e, version);
            if !ok && e.alive {
                e.tau.scale(cfg.alpha);
            }
        }
        if elig.is_empty() || batch == 0 {
            return Vec::new();
        }
        // Pass 2: proportional floors + largest-remainder top-up.
        let mut out: Vec<Assignment> = Vec::with_capacity(elig.len());
        let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(elig.len());
        let mut assigned = 0u64;
        for (i, &(actor, tau, needs_commit)) in elig.iter().enumerate() {
            let exact = batch as f64 * tau / total_tau;
            let share = exact.floor() as u64;
            assigned += share;
            fracs.push((i, exact - share as f64));
            out.push(Assignment { actor, requests: share, needs_commit });
        }
        fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut left = batch - assigned;
        for (i, _) in fracs {
            if left == 0 {
                break;
            }
            out[i].requests += 1;
            left -= 1;
        }
        out.retain(|a| a.requests > 0);
        out
    }

    /// Settlement (Algorithm 1 line 16): blend observed throughput.
    pub fn settle(&mut self, actor: ActorId, tokens: u64, elapsed_s: f64) {
        if elapsed_s <= 0.0 {
            return;
        }
        if let Some(a) = self.actors.get_mut(&actor) {
            a.tau.observe(tokens as f64 / elapsed_s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Scheduler {
        Scheduler::new(SchedulerConfig { alpha: 0.5, beta: 0.7, default_tau: 1000.0 })
    }

    fn on_version(s: &mut Scheduler, actor: ActorId, v: u64) {
        s.observe_version(actor, VersionState { active: v, staged: None });
    }

    #[test]
    fn paper_worked_example_h100_a100_split() {
        // §5.3: H100 at 5000 tok/s and A100 at 2500 split 300 -> 200/100.
        let mut s = sched();
        s.register(1, 5000.0);
        s.register(2, 2500.0);
        on_version(&mut s, 1, 3);
        on_version(&mut s, 2, 3);
        let alloc = s.allocate(3, 300);
        assert_eq!(alloc.len(), 2);
        assert_eq!(alloc[0], Assignment { actor: 1, requests: 200, needs_commit: false });
        assert_eq!(alloc[1], Assignment { actor: 2, requests: 100, needs_commit: false });
    }

    #[test]
    fn full_batch_always_assigned() {
        let mut s = sched();
        for id in 0..7 {
            s.register(id, 1000.0 + id as f64 * 137.0);
            on_version(&mut s, id, 1);
        }
        for batch in [1u64, 2, 3, 100, 301, 512] {
            let total: u64 = s.allocate(1, batch).iter().map(|a| a.requests).sum();
            assert_eq!(total, batch, "batch={batch}");
        }
    }

    #[test]
    fn version_gate_rules() {
        let mut s = sched();
        s.register(1, 1000.0); // on v: eligible
        s.register(2, 1000.0); // on v-1 with staged v: eligible + commit
        s.register(3, 1000.0); // on v-1, not staged: excluded
        s.register(4, 1000.0); // two behind: excluded
        on_version(&mut s, 1, 5);
        s.observe_version(2, VersionState { active: 4, staged: Some(5) });
        s.observe_version(3, VersionState { active: 4, staged: None });
        s.observe_version(4, VersionState { active: 3, staged: Some(4) });
        let alloc = s.allocate(5, 100);
        let actors: Vec<ActorId> = alloc.iter().map(|a| a.actor).collect();
        assert_eq!(actors, vec![1, 2]);
        assert!(!alloc[0].needs_commit);
        assert!(alloc[1].needs_commit);
    }

    #[test]
    fn incremental_staging_and_commit_notifications_drive_the_gate() {
        let mut s = sched();
        s.register(1, 1000.0);
        on_version(&mut s, 1, 4);
        // Mid-generation staging of D_5: eligible for v5 with a Commit first.
        s.note_staged(1, 5);
        let alloc = s.allocate(5, 10);
        assert_eq!(alloc.len(), 1);
        assert!(alloc[0].needs_commit);
        // Commit lands at the safe point: plain eligibility, staged cleared.
        s.note_committed(1, 5);
        let alloc = s.allocate(5, 10);
        assert!(!alloc[0].needs_commit);
        // Stale notifications never regress the state.
        s.note_staged(1, 3);
        s.note_committed(1, 2);
        let alloc = s.allocate(5, 10);
        assert_eq!(alloc.len(), 1);
        assert!(!alloc[0].needs_commit);
    }

    #[test]
    fn excluded_actor_tau_decays_and_recovers() {
        let mut s = sched();
        s.register(1, 4000.0);
        s.register(2, 4000.0);
        on_version(&mut s, 1, 2);
        on_version(&mut s, 2, 0); // two behind
        s.allocate(2, 100);
        assert!((s.tau(2).unwrap() - 2000.0).abs() < 1e-9, "alpha decay applied");
        assert!((s.tau(1).unwrap() - 4000.0).abs() < 1e-9);
        // Rejoin: gets less than half of the batch at first.
        on_version(&mut s, 2, 2);
        let alloc = s.allocate(2, 90);
        let a2 = alloc.iter().find(|a| a.actor == 2).unwrap().requests;
        assert!(a2 < 45, "rejoining actor starts conservative: {a2}");
        // Sustained performance recovers the share.
        for _ in 0..20 {
            s.settle(2, 40_000, 10.0);
        }
        assert!((s.tau(2).unwrap() - 4000.0).abs() < 100.0);
    }

    #[test]
    fn settle_blends_with_beta() {
        let mut s = sched();
        s.register(1, 1000.0);
        s.settle(1, 2000, 1.0); // observe 2000 tok/s
        // beta=0.7: 0.7*1000 + 0.3*2000 = 1300
        assert!((s.tau(1).unwrap() - 1300.0).abs() < 1e-9);
    }

    #[test]
    fn slow_actor_share_shrinks_over_time() {
        let mut s = sched();
        s.register(1, 3000.0);
        s.register(2, 3000.0);
        on_version(&mut s, 1, 1);
        on_version(&mut s, 2, 1);
        // Actor 2 persistently runs at a third of its prior.
        for _ in 0..15 {
            s.settle(1, 30_000, 10.0);
            s.settle(2, 10_000, 10.0);
        }
        let alloc = s.allocate(1, 400);
        let a1 = alloc.iter().find(|a| a.actor == 1).unwrap().requests;
        let a2 = alloc.iter().find(|a| a.actor == 2).unwrap().requests;
        assert!(a1 >= 290 && a2 <= 110, "a1={a1} a2={a2}");
    }

    #[test]
    fn bandwidth_gate_shrinks_starved_region_share() {
        // Two regions, equal taus. Region 1's observed distribution
        // throughput can only land the delta in 4x the window: its actors'
        // share drops to ~1/(1+4) of the pair-wise split.
        let mut s = sched();
        for id in 0..4u32 {
            s.register(id, 2000.0);
            on_version(&mut s, id, 1);
            s.set_region(id, (id / 2) as usize);
        }
        let payload = 200_000_000u64;
        let window = 40.0;
        s.observe_transfer(0, payload, 10.0); // delivers in 1/4 window: fine
        s.observe_transfer(1, payload, 160.0); // needs 4x the window
        let alloc = s.allocate_bandwidth_aware(1, 400, payload, window);
        let total: u64 = alloc.iter().map(|a| a.requests).sum();
        assert_eq!(total, 400, "full batch still assigned");
        let r0: u64 = alloc.iter().filter(|a| a.actor < 2).map(|a| a.requests).sum();
        let r1: u64 = alloc.iter().filter(|a| a.actor >= 2).map(|a| a.requests).sum();
        assert!(r1 > 0, "starved region is throttled, not excluded");
        // scale(r0)=1, scale(r1)=0.25 -> 320/80 exactly.
        assert_eq!(r0, 320, "r0={r0} r1={r1}");
        assert_eq!(r1, 80);
    }

    #[test]
    fn bandwidth_gate_neutral_without_observations_or_regions() {
        let mut s = sched();
        for id in 0..3u32 {
            s.register(id, 1000.0 + id as f64 * 500.0);
            on_version(&mut s, id, 2);
        }
        s.set_region(0, 0); // tagged but never observed
        let plain = s.allocate(2, 300);
        let gated = s.allocate_bandwidth_aware(2, 300, 100_000_000, 30.0);
        assert_eq!(plain, gated, "no observations: gate must be a no-op");
    }

    #[test]
    fn region_throughput_ema_blends_observations() {
        let mut s = sched();
        s.observe_transfer(3, 100_000_000, 10.0); // 10 MB/s
        assert!((s.region_bps(3).unwrap() - 1e7).abs() < 1.0);
        s.observe_transfer(3, 300_000_000, 10.0); // 30 MB/s
        // beta=0.7: 0.7*10 + 0.3*30 = 16 MB/s
        assert!((s.region_bps(3).unwrap() - 1.6e7).abs() < 1.0);
        assert!(s.region_bps(4).is_none());
    }

    #[test]
    fn dead_actor_gets_nothing() {
        let mut s = sched();
        s.register(1, 1000.0);
        s.register(2, 1000.0);
        on_version(&mut s, 1, 1);
        on_version(&mut s, 2, 1);
        s.deregister(2);
        let alloc = s.allocate(1, 50);
        assert_eq!(alloc.len(), 1);
        assert_eq!(alloc[0].actor, 1);
        assert_eq!(alloc[0].requests, 50);
    }

    #[test]
    fn no_eligible_actors_returns_empty() {
        let mut s = sched();
        s.register(1, 1000.0);
        on_version(&mut s, 1, 0);
        assert!(s.allocate(7, 100).is_empty());
    }

    #[test]
    fn prop_allocation_proportionality_and_exactness() {
        crate::util::prop::check("allocation sums to B, roughly proportional", 30, |rng| {
            let mut s = sched();
            let n = rng.range(1, 12);
            let mut taus = Vec::new();
            for id in 0..n as u32 {
                let tau = 500.0 + rng.f64() * 8000.0;
                s.register(id, tau);
                s.observe_version(id, VersionState { active: 9, staged: None });
                taus.push(tau);
            }
            let batch = rng.range(0, 2000) as u64;
            let alloc = s.allocate(9, batch);
            let total: u64 = alloc.iter().map(|a| a.requests).sum();
            assert_eq!(total, batch);
            // Proportionality within 1 request of the exact share.
            let tau_sum: f64 = taus.iter().sum();
            for a in &alloc {
                let exact = batch as f64 * taus[a.actor as usize] / tau_sum;
                assert!(
                    (a.requests as f64 - exact).abs() <= 1.0 + 1e-9,
                    "actor {} got {} want ~{exact:.2}",
                    a.actor,
                    a.requests
                );
            }
        });
    }
}
