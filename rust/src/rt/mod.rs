//! Real runtime: the full SparrowRL loop on actual compute.
//!
//! `local` runs trainer + N rollout actors in one process against the AOT
//! PJRT artifacts, with real delta checkpoints flowing trainer -> segments
//! -> staged activation, the real Job Ledger (real-clock leases +
//! acceptance predicate) and the real Algorithm-1 scheduler. `pipeline`
//! holds the step logic and both executors — the phase-sequential
//! reference and the overlapped one-step async runtime (worker thread per
//! actor, training/delta-streaming hidden inside the generation window).
//! `compute` abstracts the model backend (PJRT artifacts or the
//! deterministic synthetic engine). `net` defines the `Msg` vocabulary
//! and its TCP framing — the *entire* hub↔actor protocol every
//! `transport::api` backend carries, so one pipelined executor runs
//! unchanged over in-process mailboxes (`--transport inproc`), the
//! netsim WAN-reorder model (`--transport sim`), and real loopback
//! sockets (`--transport tcp`), with lease-driven failover when a Tcp
//! actor crashes or partitions. With a [`DistributionSpec`]
//! (`LocalRunConfig::distribution`) the InProc backend routes delta
//! segments hub → regional relay worker → peers, mirroring the
//! multi-region WAN tree of `transport::DistributionPlan` in one process
//! (see docs/ARCHITECTURE.md).

pub mod compute;
pub mod local;
pub mod net;
pub mod pipeline;

pub use compute::{Compute, ComputeShape, SyntheticCompute};
pub use local::{
    evaluate, run_local, run_local_mode, BootstrapKind, ElasticSpec, FailReason, JoinSpec,
    LeaveSpec, LocalRunConfig, RunReport, StepLog, SwapSpec, TransportKind,
};
pub use pipeline::{policy_checksum, run_with_compute, DistributionSpec, ExecMode};
