//! Real runtime: the full SparrowRL loop on actual compute.
//!
//! `local` runs trainer + N rollout actors in one process against the AOT
//! PJRT artifacts, with real delta checkpoints flowing trainer -> segments
//! -> staged activation, the real Job Ledger (leases + acceptance
//! predicate) and the real Algorithm-1 scheduler. `net` adds the
//! TCP transport so the same loop runs across processes.

pub mod local;
pub mod net;

pub use local::{run_local, LocalRunConfig, RunReport, StepLog};
