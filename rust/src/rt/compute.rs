//! Compute backends for the local runtimes.
//!
//! The RL loop (`rt/pipeline.rs`) is generic over a [`Compute`]: the PJRT
//! [`Engines`] implement it for real artifact execution, and
//! [`SyntheticCompute`] provides a deterministic, dependency-free stand-in
//! so the pipelined executor, its equivalence tests, and the overlap
//! benchmark all run in environments without compiled artifacts. The
//! synthetic backend is *honest about data flow*: generations depend on
//! the served policy bits and training mutates the master weights, so a
//! runtime bug that serves the wrong policy version or tears a commit
//! changes observable output.

use crate::actor::rollout::{generate_batch, Generation, SampleCfg};
use crate::delta::ParamSet;
use crate::runtime::{Engines, TrainState};
use crate::util::Rng;
use anyhow::Result;
use std::time::Duration;

/// Fixed batch geometry a compute backend executes.
#[derive(Clone, Copy, Debug)]
pub struct ComputeShape {
    pub b_train: usize,
    pub b_gen: usize,
    pub max_seq: usize,
}

/// What the RL loop needs from a model executor. `Sync` because the
/// pipelined runtime shares one backend across actor worker threads.
pub trait Compute: Sync {
    fn shape(&self) -> ComputeShape;

    /// One optimizer step in place on `state`; returns the loss.
    fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        mask: &[f32],
        adv: &[f32],
        lr: f32,
    ) -> Result<f32>;

    /// Sample completions for up to `b_gen` prompts on `policy`.
    fn generate(
        &self,
        policy: &ParamSet,
        prompts: &[Vec<i32>],
        cfg: SampleCfg,
        rng: &mut Rng,
    ) -> Result<Vec<Generation>>;
}

impl Compute for Engines {
    fn shape(&self) -> ComputeShape {
        ComputeShape {
            b_train: self.manifest.b_train,
            b_gen: self.manifest.b_gen,
            max_seq: self.manifest.max_seq,
        }
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        mask: &[f32],
        adv: &[f32],
        lr: f32,
    ) -> Result<f32> {
        Engines::train_step(self, state, tokens, mask, adv, lr)
    }

    fn generate(
        &self,
        policy: &ParamSet,
        prompts: &[Vec<i32>],
        cfg: SampleCfg,
        rng: &mut Rng,
    ) -> Result<Vec<Generation>> {
        generate_batch(self, policy, prompts, cfg, rng)
    }
}

/// Deterministic artifact-free backend. Optional per-call delays emulate
/// accelerator latency so overlap benchmarks measure real concurrency.
#[derive(Clone, Debug)]
pub struct SyntheticCompute {
    pub shape: ComputeShape,
    pub vocab: usize,
    /// Sleep per `train_step` call (zero in unit tests).
    pub train_delay: Duration,
    /// Sleep per `generate` call (one generation batch).
    pub gen_delay: Duration,
    /// Update-sparsity regime: each train step touches `len / update_div`
    /// elements per tensor (min 1). 128 reproduces the historical
    /// behavior; the bench harness sweeps 16 (dense) to 1024 (sparse).
    pub update_div: usize,
}

impl SyntheticCompute {
    pub fn new(b_train: usize, b_gen: usize, max_seq: usize) -> SyntheticCompute {
        SyntheticCompute {
            shape: ComputeShape { b_train, b_gen, max_seq },
            vocab: 64,
            train_delay: Duration::ZERO,
            gen_delay: Duration::ZERO,
            update_div: 128,
        }
    }

    /// Attach emulated compute latencies (for overlap benchmarking).
    pub fn with_delays(mut self, train: Duration, gen: Duration) -> SyntheticCompute {
        self.train_delay = train;
        self.gen_delay = gen;
        self
    }

    /// Select the update-sparsity regime: each train step touches
    /// `len / div` elements per tensor (min 1), so larger divisors give
    /// sparser deltas. Must be >= 1.
    pub fn with_update_divisor(mut self, div: usize) -> SyntheticCompute {
        assert!(div >= 1, "update divisor must be >= 1");
        self.update_div = div;
        self
    }

    /// FNV-1a fingerprint of a strided sample of the policy bits: cheap,
    /// but any committed delta perturbs it with overwhelming probability.
    fn policy_fingerprint(policy: &ParamSet) -> u64 {
        let mut fp = 0xcbf2_9ce4_8422_2325u64;
        for t in &policy.tensors {
            let stride = (t.len() / 64).max(1);
            for b in t.iter().step_by(stride) {
                fp = (fp ^ b.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        fp
    }
}

impl Compute for SyntheticCompute {
    fn shape(&self) -> ComputeShape {
        self.shape
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        _mask: &[f32],
        adv: &[f32],
        lr: f32,
    ) -> Result<f32> {
        if !self.train_delay.is_zero() {
            std::thread::sleep(self.train_delay);
        }
        state.step += 1;
        // Deterministic pseudo-gradient seeded by the batch content and the
        // optimizer step, so identical inputs => identical new weights.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |x: u64| {
            h = (h ^ x).wrapping_mul(0x0000_0100_0000_01b3);
        };
        for &t in tokens {
            mix(t as u32 as u64);
        }
        for &a in adv {
            mix(a.to_bits() as u64);
        }
        mix(state.step);
        let mut rng = Rng::new(h);
        for t in state.masters.iter_mut() {
            let touched = (t.len() / self.update_div).max(1);
            for _ in 0..touched {
                let i = rng.range(0, t.len());
                t[i] -= lr * (rng.f32() * 2.0 - 1.0);
            }
        }
        Ok(1.0 / (state.step as f32).sqrt())
    }

    fn generate(
        &self,
        policy: &ParamSet,
        prompts: &[Vec<i32>],
        cfg: SampleCfg,
        rng: &mut Rng,
    ) -> Result<Vec<Generation>> {
        assert!(prompts.len() <= self.shape.b_gen, "{} prompts > b_gen", prompts.len());
        if !self.gen_delay.is_zero() {
            std::thread::sleep(self.gen_delay);
        }
        let fp = Self::policy_fingerprint(policy);
        let mut out = Vec::with_capacity(prompts.len());
        for p in prompts {
            let prompt_len = p.len().min(self.shape.max_seq - 1);
            let mut tokens = p[..prompt_len].to_vec();
            let room = self.shape.max_seq - prompt_len;
            for _ in 0..cfg.max_new_tokens.min(room) {
                // Token stream depends on both the RNG lane and the policy
                // bits; avoid PAD/EOS so lengths stay deterministic.
                let r = rng.next_u64() ^ fp;
                tokens.push(3 + (r % (self.vocab as u64 - 3)) as i32);
            }
            out.push(Generation { prompt_len, tokens });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::ModelLayout;

    fn setup() -> (ModelLayout, SyntheticCompute) {
        (ModelLayout::transformer("synown", 64, 16, 2, 32), SyntheticCompute::new(8, 4, 32))
    }

    #[test]
    fn synthetic_train_is_deterministic_and_mutates_weights() {
        let (l, c) = setup();
        let mut rng = Rng::new(1);
        let mut a = TrainState::init(&l, &mut rng);
        let before = a.to_policy();
        let tokens = vec![5i32; 8 * 32];
        let mask = vec![1.0f32; 8 * 32];
        let adv = vec![0.5f32; 8];
        let la = c.train_step(&mut a, &tokens, &mask, &adv, 1e-2).unwrap();
        let mut rng2 = Rng::new(1);
        let mut b = TrainState::init(&l, &mut rng2);
        let lb = c.train_step(&mut b, &tokens, &mask, &adv, 1e-2).unwrap();
        assert_eq!(la, lb);
        assert_eq!(a.to_policy(), b.to_policy(), "same inputs, same weights");
        assert_ne!(a.to_policy(), before, "training changed the policy");
    }

    #[test]
    fn update_divisor_controls_touched_fraction() {
        let (l, _) = setup();
        let tokens = vec![5i32; 8 * 32];
        let mask = vec![1.0f32; 8 * 32];
        let adv = vec![0.5f32; 8];
        let changed = |div: usize| {
            let c = SyntheticCompute::new(8, 4, 32).with_update_divisor(div);
            let mut st = TrainState::init(&l, &mut Rng::new(1));
            let before = st.to_policy();
            c.train_step(&mut st, &tokens, &mask, &adv, 1e-2).unwrap();
            let after = st.to_policy();
            before
                .tensors
                .iter()
                .zip(&after.tensors)
                .map(|(a, b)| a.iter().zip(b.iter()).filter(|(x, y)| x != y).count())
                .sum::<usize>()
        };
        let dense = changed(16);
        let sparse = changed(1024);
        assert!(
            dense > sparse,
            "divisor 16 must touch more elements than 1024 ({dense} vs {sparse})"
        );
        assert!(sparse >= 1, "even the sparsest regime touches something");
    }

    #[test]
    fn synthetic_generation_depends_on_policy_and_rng() {
        let (l, c) = setup();
        let mut rng = Rng::new(2);
        let st = TrainState::init(&l, &mut rng);
        let p0 = st.to_policy();
        let prompts = vec![vec![4, 5, 6], vec![7, 8]];
        let cfg = SampleCfg { temperature: 0.8, max_new_tokens: 4 };
        let a = c.generate(&p0, &prompts, cfg, &mut Rng::new(7)).unwrap();
        let b = c.generate(&p0, &prompts, cfg, &mut Rng::new(7)).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].tokens, b[0].tokens, "same policy + seed => same tokens");
        // A different policy changes the completions (stale vs fresh matters).
        let mut st2 = TrainState::init(&l, &mut Rng::new(3));
        let tokens = vec![5i32; 8 * 32];
        c.train_step(&mut st2, &tokens, &[1.0; 256], &[1.0; 8], 5e-2).unwrap();
        let p1 = st2.to_policy();
        assert_ne!(p1, p0);
        let d = c.generate(&p1, &prompts, cfg, &mut Rng::new(7)).unwrap();
        assert_ne!(a[0].tokens, d[0].tokens, "policy bits reach the output");
        // Shape invariants.
        for (g, p) in a.iter().zip(&prompts) {
            assert_eq!(g.prompt_len, p.len());
            assert_eq!(g.tokens.len(), p.len() + 4);
            assert!(g.tokens[g.prompt_len..].iter().all(|&t| t >= 3));
        }
    }
}
